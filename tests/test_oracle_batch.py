"""Batched oracle execution layer: vectorized cache + OracleBatch semantics,
coalesced BAS labelling, the served-scorer integration, and the serving-layer
satellite fixes (stable softmax, NL conjunctions, mid-flight admission)."""
import numpy as np
import pytest

from repro.core import ArrayOracle, FnOracle, OracleBatch
from repro.core.oracle import BudgetExceeded
from repro.data import make_clustered_tables


# ----------------------------------------------------------------------------
# vectorized cache + batch/flush ledger semantics
# ----------------------------------------------------------------------------

def _counting_oracle(n=64):
    """FnOracle labelling (i+j) % 2, with a log of every backend batch."""
    log = []

    def fn(idx):
        log.append(np.array(idx))
        return (idx.sum(axis=1) % 2).astype(np.float64)

    o = FnOracle(fn)
    o.bind_sizes((n, n))
    return o, log


def test_dedup_across_requests_charges_once():
    oracle, log = _counting_oracle()
    batch = OracleBatch(oracle)
    a = np.array([[0, 1], [2, 3], [4, 5]])
    b = np.array([[2, 3], [4, 5], [6, 7]])       # overlaps a on two tuples
    c = np.array([[0, 1], [0, 1]])               # duplicate rows, all in a
    ha, hb, hc = batch.submit(a), batch.submit(b), batch.submit(c)
    batch.flush()
    assert oracle.calls == 4                     # unique across all requests
    assert oracle.requests == 8
    assert oracle.batches == 1                   # one backend execution
    assert len(log) == 1 and len(log[0]) == 4
    np.testing.assert_array_equal(ha.labels, (a.sum(1) % 2))
    np.testing.assert_array_equal(hb.labels, (b.sum(1) % 2))
    np.testing.assert_array_equal(hc.labels, (c.sum(1) % 2))
    # a second batch over already-seen tuples is free
    batch2 = OracleBatch(oracle)
    h = batch2.submit(b)
    batch2.flush()
    assert oracle.calls == 4 and oracle.batches == 1
    np.testing.assert_array_equal(h.labels, (b.sum(1) % 2))
    assert oracle.dedup_ratio == pytest.approx(1 - 4 / 11)


def test_empty_flush_is_guaranteed_noop():
    """flush() / flush_async() on an empty pending set must be a no-op: no
    backend call, no budget charge, counters untouched — even when the
    budget is already fully spent."""
    oracle, log = _counting_oracle()
    oracle.set_budget(2)
    oracle.label(np.array([[0, 0], [1, 1]]))         # budget fully spent
    before = (oracle.calls, oracle.requests, oracle.batches)

    batch = OracleBatch(oracle)
    batch.flush()                                    # nothing pending: no-op
    fut = batch.flush_async()
    assert fut.done() and fut.exception() is None
    # zero-row submissions are equally free
    h = batch.submit(np.zeros((0, 2), np.int64))
    batch.flush()
    assert len(h.labels) == 0
    assert (oracle.calls, oracle.requests, oracle.batches) == before
    assert len(log) == 1                             # no new backend call


def test_budget_exceeded_is_atomic():
    oracle, log = _counting_oracle()
    oracle.set_budget(5)
    oracle.label(np.array([[0, 0], [1, 1], [2, 2]]))
    assert oracle.calls == 3
    requests_before = oracle.requests
    batch = OracleBatch(oracle)
    batch.submit(np.array([[1, 1], [2, 2]]))     # cached
    h = batch.submit(np.array([[3, 3], [4, 4], [5, 5]]))  # 3 new > 2 remaining
    with pytest.raises(BudgetExceeded):
        batch.flush()
    # nothing was labelled, cached, or counted by the failed flush
    assert oracle.calls == 3
    assert oracle.requests == requests_before
    assert oracle.batches == 1
    assert len(log) == 1
    assert not oracle._cached_mask(oracle._encode(np.array([[3, 3]])))[0]
    # the cache itself is intact: cached tuples still label for free
    oracle.label(np.array([[0, 0], [1, 1]]))
    assert oracle.calls == 3
    # the batch stays pending: raising the budget lets the same flush succeed
    oracle.set_budget(10)
    batch.flush()
    np.testing.assert_array_equal(h.labels, [0.0, 0.0, 0.0])
    assert oracle.calls == 6


def test_backend_failure_leaves_batch_retryable():
    """A transient _label failure (device OOM etc.) must leave the oracle and
    the batch exactly as they were, so the same flush can be retried."""
    state = {"fail": True}

    def fn(idx):
        if state["fail"]:
            raise RuntimeError("transient backend error")
        return (idx.sum(axis=1) % 2).astype(np.float64)

    oracle = FnOracle(fn)
    oracle.bind_sizes((16, 16))
    batch = OracleBatch(oracle)
    h = batch.submit(np.array([[1, 2], [3, 4]]))
    with pytest.raises(RuntimeError):
        batch.flush()
    assert oracle.calls == 0 and oracle.requests == 0 and oracle.batches == 0
    state["fail"] = False
    batch.flush()                                # same batch, retried
    np.testing.assert_array_equal(h.labels, [1.0, 1.0])
    assert oracle.calls == 2 and oracle.requests == 2


def test_rebind_between_submit_and_flush():
    """Keys are encoded at flush time: a bind_sizes rebind between submit and
    flush (shared oracle, second query starts) must not corrupt resolution."""
    oracle, _ = _counting_oracle()          # bound to (64, 64)
    batch = OracleBatch(oracle)
    idx = np.array([[0, 50], [3, 7]])
    h = batch.submit(idx)
    oracle.bind_sizes((70, 70))             # rebind before the flush
    batch.flush()
    np.testing.assert_array_equal(h.labels, idx.sum(axis=1) % 2)
    assert oracle.calls == 2


def test_failed_rebind_leaves_encoding_consistent():
    oracle, _ = _counting_oracle()          # bound to (64, 64)
    idx = np.array([[0, 50], [1, 2]])
    want = oracle.label(idx)
    with pytest.raises(ValueError):
        oracle.bind_sizes((50, 50))         # (0, 50) does not fit
    # cache must still be keyed consistently under the original sizes
    np.testing.assert_array_equal(oracle.label(idx), want)
    assert oracle.calls == 2
    np.testing.assert_array_equal(
        oracle.label(np.array([[1, 0]])), [1.0]
    )


def test_vectorized_cache_matches_dict_semantics():
    """Random request streams give the same labels the old dict cache gave."""
    rng = np.random.default_rng(0)
    truth = (rng.random((40, 30)) < 0.3).astype(np.int8)
    oracle = ArrayOracle(truth)
    dict_cache: dict = {}
    for _ in range(20):
        n = int(rng.integers(1, 50))
        idx = np.stack(
            [rng.integers(0, 40, size=n), rng.integers(0, 30, size=n)], axis=1
        )
        got = oracle.label(idx)
        want = np.empty(n, np.float64)
        for i, (r, c) in enumerate(idx):
            key = (int(r), int(c))
            if key not in dict_cache:
                dict_cache[key] = float(truth[r, c])
            want[i] = dict_cache[key]
        np.testing.assert_array_equal(got, want)
    assert oracle.calls == len(dict_cache)


def test_unbound_oracle_packs_keys():
    calls = []
    oracle = FnOracle(lambda idx: (idx[:, 0] > idx[:, 1]).astype(np.float64))
    idx = np.array([[5, 3], [1, 2], [5, 3]])
    out = oracle.label(idx)
    np.testing.assert_array_equal(out, [1.0, 0.0, 1.0])
    assert oracle.calls == 2
    # binding sizes afterwards re-keys the cache without re-labelling
    oracle.bind_sizes((10, 10))
    out2 = oracle.label(idx)
    np.testing.assert_array_equal(out2, out)
    assert oracle.calls == 2


def test_unbound_packing_roundtrips_all_widths():
    """The unbound bit packing must be self-inverse for every tuple width
    (63//(63//k) != k for k=8, 11, ... — the width is stored, not re-derived)."""
    for k in (1, 2, 3, 4, 8, 11):
        seen = []

        def fn(idx, seen=seen):
            seen.append(np.array(idx))
            return (idx.sum(axis=1) % 2).astype(np.float64)

        oracle = FnOracle(fn)
        rng = np.random.default_rng(k)
        idx = rng.integers(0, 1 << (63 // k), size=(5, k))
        out = oracle.label(idx)
        assert seen[0].shape[1] == k
        np.testing.assert_array_equal(out, idx.sum(axis=1) % 2)
        with pytest.raises(ValueError):
            oracle.label(np.zeros((1, k + 1), np.int64))  # width mismatch


def test_1d_and_3way_indices():
    oracle = FnOracle(lambda idx: (idx.sum(axis=1) % 3 == 0).astype(np.float64))
    oracle.bind_sizes((100,))
    np.testing.assert_array_equal(oracle.label(np.array([0, 3, 4])), [1, 1, 0])
    chain = FnOracle(lambda idx: (idx.sum(axis=1) % 2).astype(np.float64))
    chain.bind_sizes((8, 9, 10))
    idx = np.array([[1, 2, 3], [0, 0, 0], [7, 8, 9]])
    np.testing.assert_array_equal(chain.label(idx), idx.sum(1) % 2)
    assert chain.calls == 3


# ----------------------------------------------------------------------------
# coalesced BAS: few backend batches, estimates identical to eager labelling
# ----------------------------------------------------------------------------

def test_bas_batches_small_and_estimates_bit_identical(monkeypatch):
    """The batched pipeline must issue O(stages) backend batches — not one
    per stratum/call-site — and coalescing must not change the statistics:
    estimates are bit-identical to labelling each call site eagerly."""
    from repro.core import Agg, Query, bas, run_bas

    ds = make_clustered_tables(120, 120, n_entities=200, noise=0.4, seed=5)

    def run(seed):
        q = Query(spec=ds.spec(), agg=Agg.COUNT, oracle=ds.oracle(), budget=2500)
        res = run_bas(q, seed=seed)
        return res, q.oracle

    res_batched, oracle_batched = run(3)

    class EagerBatch(OracleBatch):
        """Per-call-site behavior: every submit is its own flush."""

        def submit(self, idx):
            h = super().submit(idx)
            super().flush()
            return h

    monkeypatch.setattr(bas, "OracleBatch", EagerBatch)
    res_eager, oracle_eager = run(3)

    assert res_batched.estimate == res_eager.estimate          # bit-identical
    assert res_batched.ci.lo == res_eager.ci.lo
    assert res_batched.ci.hi == res_eager.ci.hi
    assert oracle_batched.calls == oracle_eager.calls
    n_strata = res_batched.detail["num_strata"]
    assert n_strata >= 5
    # eager: >= one backend batch per stratum just for the pilot
    assert oracle_eager.batches >= n_strata
    # batched: pilot + blocking + <=4 top-up rounds
    assert oracle_batched.batches <= 6
    assert res_batched.detail["oracle"]["batches"] == oracle_batched.batches


def test_streaming_bas_also_coalesced():
    from repro.core import Agg, Query, run_bas_streaming

    ds = make_clustered_tables(150, 150, n_entities=250, noise=0.45, seed=9)
    q = Query(spec=ds.spec(), agg=Agg.COUNT, oracle=ds.oracle(), budget=3000)
    res = run_bas_streaming(q, seed=1)
    assert np.isfinite(res.estimate)
    assert q.oracle.batches <= 6
    assert res.detail["oracle"]["dedup_ratio"] >= 0.0


# ----------------------------------------------------------------------------
# ModelOracle through PairScorer (serving integration)
# ----------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_scorer():
    import jax

    from repro.configs import get_smoke_config
    from repro.data.pipeline import ByteTokenizer, pair_example
    from repro.models import init_params
    from repro.serve.serve_loop import PairScorer

    tok = ByteTokenizer()
    cfg = get_smoke_config(
        "qwen2-1.5b", vocab_size=tok.vocab_size, remat=False, num_layers=1,
        d_model=32, num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
    )
    params = init_params(cfg, jax.random.key(0))
    rec1 = [f"acme unit {i:03d}" for i in range(40)]
    rec2 = [f"acme dept {j:03d}" for j in range(40)]

    def tok_pair(pair):
        t, _ = pair_example(tok, rec1[pair[0]], rec2[pair[1]], None, 48)
        return t[t != tok.PAD]

    return PairScorer(cfg, params, tok_pair, tok.YES, tok.NO, max_len=48,
                      batch_size=32)


def test_model_oracle_through_pair_scorer(tiny_scorer):
    from repro.core import Agg, ModelOracle, Query, run_bas

    ds = make_clustered_tables(40, 40, n_entities=60, noise=0.4, seed=11)
    oracle = ModelOracle(tiny_scorer, threshold=0.5)
    q = Query(spec=ds.spec(), agg=Agg.COUNT, oracle=oracle, budget=400)
    res = run_bas(q, seed=0)
    assert np.isfinite(res.estimate)
    assert oracle.calls <= 400
    assert oracle.calls == tiny_scorer.pairs_scored   # flushes are pre-deduped
    # coalescing bound: a handful of pipeline-stage batches, and the backend
    # sees ceil(unique/batch_size) device batches + <=1 tail pad per flush
    assert oracle.batches <= 6
    assert tiny_scorer.forward_batches <= (
        int(np.ceil(oracle.calls / tiny_scorer.batch_size)) + oracle.batches
    )


def test_pair_scorer_sharded_path_matches_unsharded(tiny_scorer):
    """The shard_map data-parallel path (1-device mesh here) must agree with
    the plain jitted path."""
    from repro.launch.mesh import make_host_mesh
    from repro.serve.serve_loop import PairScorer

    mesh = make_host_mesh()
    sharded = PairScorer(
        tiny_scorer.cfg, tiny_scorer.params, tiny_scorer.tokenize_pair,
        tiny_scorer.yes_id, tiny_scorer.no_id, max_len=48, batch_size=16,
        mesh=mesh,
    )
    rng = np.random.default_rng(0)
    pairs = np.stack([rng.integers(0, 40, 20), rng.integers(0, 40, 20)], axis=1)
    np.testing.assert_allclose(
        sharded.score(pairs), tiny_scorer.score(pairs), atol=2e-2
    )


def test_stable_softmax_no_overflow():
    from repro.serve.serve_loop import _stable_yes_no_prob

    lg = np.array([[2000.0, -2000.0], [-2000.0, 2000.0], [0.0, 0.0],
                   [800.0, 799.0]])
    p = _stable_yes_no_prob(lg)
    assert np.isfinite(p).all()
    assert p[0] == pytest.approx(1.0)
    assert p[1] == pytest.approx(0.0)
    assert p[2] == pytest.approx(0.5)
    assert p[3] == pytest.approx(1 / (1 + np.exp(-1.0)))


# ----------------------------------------------------------------------------
# engine: NL conjunction syntax
# ----------------------------------------------------------------------------

def test_parse_nl_conjunction():
    from repro.core import parse_query

    pq = parse_query(
        "SELECT COUNT(*) FROM a JOIN b JOIN c ON NL('a matches b') AND "
        "NL('b matches c') ORACLE BUDGET 100 WITH PROBABILITY 0.9"
    )
    assert pq.table_names == ["a", "b", "c"]
    assert pq.nl_conditions == ["a matches b", "b matches c"]
    assert pq.nl_condition == "a matches b"
    assert pq.budget == 100

    # single predicate still parses (and applies to all edges)
    pq = parse_query("SELECT COUNT(*) FROM a JOIN b JOIN c ON NL('x')")
    assert pq.nl_conditions == ["x"]

    # predicate count must match the number of join edges
    with pytest.raises(ValueError):
        parse_query("SELECT COUNT(*) FROM a JOIN b ON NL('x') AND NL('y') AND NL('z')")


def test_engine_threads_predicate_list():
    from repro.core import Catalog, JoinMLEngine, PairChainOracle, Table
    from repro.core.similarity import normalize

    rng = np.random.default_rng(0)
    cat = Catalog()
    for name in ("a", "b", "c"):
        cat.register(Table(name, normalize(rng.standard_normal((12, 8)))))
    seen = {}

    def factory(nl, names):
        seen["nl"], seen["names"] = nl, names
        edges = [
            (rng.random((12, 12)) < 0.2).astype(np.int8) for _ in range(2)
        ]
        return PairChainOracle(edges)

    eng = JoinMLEngine(cat, factory)
    res = eng.execute(
        "SELECT COUNT(*) FROM a JOIN b JOIN c ON NL('a~b') AND NL('b~c') "
        "ORACLE BUDGET 300",
        method="bas",
    )
    assert seen["nl"] == ["a~b", "b~c"]
    assert seen["names"] == ["a", "b", "c"]
    assert np.isfinite(res.estimate)


# ----------------------------------------------------------------------------
# ContinuousBatcher: mid-flight admission after global_pos > 0
# ----------------------------------------------------------------------------

def _tiny_decode_cfg(arch="llama3.2-1b", **kw):
    from repro.configs import get_smoke_config

    return get_smoke_config(
        arch, remat=False, num_layers=1, d_model=32, num_heads=2,
        num_kv_heads=2, head_dim=16, d_ff=64, vocab_size=64, **kw
    )


def test_continuous_batcher_mid_flight_admission_matches_solo():
    """A request admitted into a reused slot after global_pos > 0 must decode
    exactly what it decodes alone (no stale-KV contamination)."""
    import jax

    from repro.models import init_params
    from repro.serve.serve_loop import ContinuousBatcher, Request

    cfg = _tiny_decode_cfg()
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(1)
    pa = rng.integers(7, 60, size=6).astype(np.int32)
    pb = rng.integers(7, 60, size=4).astype(np.int32)

    cb = ContinuousBatcher(cfg, params, batch_size=1, max_len=64, eos_id=1)
    cb.submit(Request(uid=0, prompt=pa, max_new_tokens=4))
    cb.submit(Request(uid=1, prompt=pb, max_new_tokens=4))
    done = cb.run_until_done(max_steps=200)
    assert len(done) == 2
    assert cb.global_pos > 0
    out_b = next(r for r in done if r.uid == 1).out_tokens

    solo = ContinuousBatcher(cfg, params, batch_size=1, max_len=64, eos_id=1)
    solo.submit(Request(uid=1, prompt=pb, max_new_tokens=4))
    ref = solo.run_until_done(max_steps=100)[0].out_tokens
    assert out_b == ref


def test_continuous_batcher_overlong_prompt_terminates():
    """A prompt that exceeds the KV-cache capacity terminates cleanly instead
    of clobbering the last cache position forever."""
    import jax

    from repro.models import init_params
    from repro.serve.serve_loop import ContinuousBatcher, Request

    cfg = _tiny_decode_cfg()
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(3)
    long_prompt = rng.integers(7, 60, size=30).astype(np.int32)

    cb = ContinuousBatcher(cfg, params, batch_size=1, max_len=16, eos_id=1)
    cb.submit(Request(uid=0, prompt=long_prompt, max_new_tokens=8))
    done = cb.run_until_done(max_steps=100)
    assert len(done) == 1 and done[0].done
    assert cb.pos[0] <= cb.max_len   # never wrote past capacity

    # recurrent families have no positional capacity to exhaust: the same
    # overlong prompt must decode to completion, not get truncated
    import jax as _jax

    rcfg = _tiny_decode_cfg("rwkv6-1.6b")
    rparams = init_params(rcfg, _jax.random.key(0))
    rcb = ContinuousBatcher(rcfg, rparams, batch_size=1, max_len=16, eos_id=1)
    rcb.submit(Request(uid=0, prompt=long_prompt, max_new_tokens=3))
    rdone = rcb.run_until_done(max_steps=100)
    assert len(rdone) == 1
    assert len(rdone[0].out_tokens) >= 1


def test_continuous_batcher_gated_admission_recurrent():
    """Recurrent families cannot rewind per-slot state: admission is gated,
    and a post-drain reset still decodes later requests correctly."""
    import jax

    from repro.models import init_params
    from repro.serve.serve_loop import ContinuousBatcher, Request

    cfg = _tiny_decode_cfg("rwkv6-1.6b")
    params = init_params(cfg, jax.random.key(0))
    assert cfg.family == "ssm"
    rng = np.random.default_rng(2)
    pa = rng.integers(7, 60, size=5).astype(np.int32)
    pb = rng.integers(7, 60, size=3).astype(np.int32)

    # batch_size=2: the late request must NOT enter the idle slot mid-wave
    # (recurrent state there has been absorbing pad tokens) — it waits for
    # the drain + reset and still decodes exactly like a solo run
    cb = ContinuousBatcher(cfg, params, batch_size=2, max_len=64, eos_id=1)
    assert not cb.per_slot_pos
    cb.submit(Request(uid=0, prompt=pa, max_new_tokens=3))
    cb.step()                      # wave 1 started: only request A on board
    cb.submit(Request(uid=1, prompt=pb, max_new_tokens=3))
    assert cb.global_pos > 0
    done = cb.run_until_done(max_steps=200)
    assert len(done) == 2
    out_b = next(r for r in done if r.uid == 1).out_tokens

    solo = ContinuousBatcher(cfg, params, batch_size=2, max_len=64, eos_id=1)
    solo.submit(Request(uid=1, prompt=pb, max_new_tokens=3))
    ref = solo.run_until_done(max_steps=100)[0].out_tokens
    assert out_b == ref
