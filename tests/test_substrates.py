"""Substrate tests: optimizer, train loop, checkpointing (atomic/async/
reshard), fault tolerance, data pipeline, serving."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import init_params
from repro.train import (
    OptimizerConfig,
    adamw_update,
    compress_grads,
    init_opt_state,
    lr_schedule,
    make_train_step,
)


def test_lr_schedule_shape():
    cfg = OptimizerConfig(peak_lr=1.0, warmup_steps=10, decay_steps=100)
    lrs = [float(lr_schedule(jnp.int32(s), cfg)) for s in [0, 5, 10, 50, 100, 200]]
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0, abs=0.11)
    assert lrs[3] < 1.0
    assert lrs[-1] == pytest.approx(cfg.min_lr_ratio, abs=1e-3)


def test_adamw_reduces_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = init_opt_state(params)
    cfg = OptimizerConfig(peak_lr=0.5, warmup_steps=0, decay_steps=1000,
                          weight_decay=0.0)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(params, grads, opt, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.6


def test_grad_compression_roundtrip():
    g = {"a": jnp.array([1.234e-3, -5.6, 0.0])}
    for mode, atol in (("none", 0.0), ("bf16", 0.05), ("int8", 5.6 / 127 / 2 + 1e-6)):
        out = compress_grads(g, mode)
        np.testing.assert_allclose(
            np.asarray(out["a"]), np.asarray(g["a"]), rtol=0.05, atol=atol
        )


@pytest.mark.slow
def test_train_step_microbatch_equivalence():
    """Gradient accumulation must match the single-batch gradient."""
    cfg = get_smoke_config("llama3.2-1b", remat=False)
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)))}
    opt = init_opt_state(params)
    ocfg = OptimizerConfig(peak_lr=0.0, warmup_steps=0, weight_decay=0.0)
    s1 = make_train_step(cfg, ocfg, num_microbatches=1)
    s4 = make_train_step(cfg, ocfg, num_microbatches=4)
    _, _, m1 = s1(params, opt, batch)
    _, _, m4 = s4(params, opt, batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=2e-2)
    assert float(m1["grad_norm"]) == pytest.approx(float(m4["grad_norm"]), rel=3e-2)


@pytest.mark.slow
def test_train_loop_loss_decreases():
    """A few hundred optimizer steps on a tiny oracle model fit a small
    synthetic pair dataset (e2e learnability of the substrate)."""
    from repro.data.pipeline import ByteTokenizer, make_entity_corpus, make_pair_batch

    tok = ByteTokenizer()
    cfg = get_smoke_config("qwen2-1.5b", vocab_size=tok.vocab_size, remat=False)
    params = init_params(cfg, jax.random.key(0))
    opt = init_opt_state(params)
    ocfg = OptimizerConfig(peak_lr=3e-3, warmup_steps=5, decay_steps=80)
    step_fn = jax.jit(make_train_step(cfg, ocfg))
    records, ids = make_entity_corpus(16, 3, noise=0.05, seed=0)
    rng = np.random.default_rng(0)
    losses = []
    for s in range(60):
        batch = make_pair_batch(tok, records, ids, batch=8, max_len=48, rng=rng)
        batch = {"tokens": jnp.asarray(batch["tokens"]),
                 "loss_mask": jnp.asarray(batch["loss_mask"])}
        params, opt, m = step_fn(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8
    assert np.isfinite(losses).all()


# ----------------------------------------------------------------------------
# checkpointing
# ----------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    from repro.checkpoint.checkpoint import latest_step, restore, save

    tree = {
        "w": jnp.arange(12, dtype=jnp.bfloat16).reshape(3, 4),
        "nested": {"b": jnp.ones((2,), jnp.float32)},
        "step": jnp.int32(7),
    }
    d = save(str(tmp_path), 7, tree, extra={"note": "x"})
    assert os.path.basename(d) == "step_00000007"
    assert latest_step(str(tmp_path)) == 7
    out, manifest = restore(str(tmp_path), 7, tree)
    assert manifest["extra"]["note"] == "x"
    np.testing.assert_array_equal(np.asarray(out["w"], np.float32),
                                  np.asarray(tree["w"], np.float32))
    # no tmp dirs left behind
    assert not [f for f in os.listdir(tmp_path) if f.startswith(".tmp")]


def test_checkpoint_async_and_cleanup(tmp_path):
    from repro.checkpoint.checkpoint import AsyncCheckpointer, latest_step

    ck = AsyncCheckpointer(str(tmp_path), keep_last=2)
    tree = {"w": jnp.zeros((4,))}
    for s in (1, 2, 3):
        ck.save(s, tree)
    ck.wait()
    assert latest_step(str(tmp_path)) == 3
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2  # cleanup kept last 2


def test_checkpoint_reshard_restore(tmp_path):
    """Restore onto different shardings (elastic scaling path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.checkpoint.checkpoint import restore, save
    from repro.launch.mesh import make_host_mesh

    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    save(str(tmp_path), 1, tree)
    mesh = make_host_mesh()
    sh = {"w": NamedSharding(mesh, P(None, None))}
    out, _ = restore(str(tmp_path), 1, tree, shardings=sh)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
    assert out["w"].sharding == sh["w"]


def test_train_restart_resumes_identically(tmp_path):
    """Crash after step k, restore, continue -> identical params as an
    uninterrupted run (determinism of loader + checkpoint fidelity)."""
    from repro.checkpoint.checkpoint import restore_latest, save
    from repro.runtime.fault_tolerance import DeterministicSkipper

    cfg = get_smoke_config("llama3.2-1b", remat=False, num_layers=1, d_model=32,
                           num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                           vocab_size=64)
    ocfg = OptimizerConfig(peak_lr=1e-3, warmup_steps=0)
    step_fn = jax.jit(make_train_step(cfg, ocfg))
    skipper = DeterministicSkipper(seed=42)

    def batch_at(s):
        rng = skipper.batch_rng(s)
        return {"tokens": jnp.asarray(rng.integers(0, 64, (2, 12)))}

    # uninterrupted run of 6 steps
    p = init_params(cfg, jax.random.key(1))
    o = init_opt_state(p)
    for s in range(6):
        p, o, _ = step_fn(p, o, batch_at(s))
    ref = p

    # interrupted: 3 steps, checkpoint, "crash", restore, 3 more
    p2 = init_params(cfg, jax.random.key(1))
    o2 = init_opt_state(p2)
    for s in range(3):
        p2, o2, _ = step_fn(p2, o2, batch_at(s))
    save(str(tmp_path), 3, {"params": p2, "opt": o2})
    restored, _ = restore_latest(str(tmp_path), {"params": p2, "opt": o2})
    p3, o3 = restored["params"], restored["opt"]
    for s in range(3, 6):
        p3, o3, _ = step_fn(p3, o3, batch_at(s))
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(p3)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-6
        )


# ----------------------------------------------------------------------------
# fault tolerance
# ----------------------------------------------------------------------------

def test_retry_with_backoff():
    from repro.runtime.fault_tolerance import retry_with_backoff

    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return "ok"

    assert retry_with_backoff(flaky, base_delay=0.001) == "ok"
    assert calls["n"] == 3
    with pytest.raises(ValueError):
        retry_with_backoff(lambda: (_ for _ in ()).throw(ValueError()), base_delay=0.001)


def test_straggler_monitor():
    from repro.runtime.fault_tolerance import StragglerMonitor

    hits = []
    mon = StragglerMonitor(threshold=3.0, callback=hits.append)
    for s in range(10):
        mon.record(s, 0.1)
    mon.record(10, 1.0)  # 10x median -> straggler
    assert len(hits) == 1 and hits[0].ratio > 3.0


def test_preemption_checkpoint_flow(tmp_path):
    from repro.checkpoint.checkpoint import latest_step, save
    from repro.runtime.fault_tolerance import PreemptionHandler

    h = PreemptionHandler()
    saved = []
    for s in range(5):
        if s == 2:
            h.simulate()
        if h.preempted:
            save(str(tmp_path), s, {"x": jnp.zeros(())})
            saved.append(s)
            break
    assert saved == [2]
    assert latest_step(str(tmp_path)) == 2


# ----------------------------------------------------------------------------
# data pipeline
# ----------------------------------------------------------------------------

def test_tokenizer_roundtrip():
    from repro.data.pipeline import ByteTokenizer

    tok = ByteTokenizer()
    s = "Acme Corp #42 ünïcode"
    assert tok.decode(tok.encode(s)) == s


def test_sharded_loader_determinism_and_sharding():
    from repro.data.pipeline import ShardedLoader

    def batch_fn(rng):
        return {"x": rng.integers(0, 100, (8, 3))}

    l0 = ShardedLoader(batch_fn, 8, num_hosts=2, host_id=0, seed=1)
    l1 = ShardedLoader(batch_fn, 8, num_hosts=2, host_id=1, seed=1)
    s0, b0 = next(l0)
    s1, b1 = next(l1)
    assert s0 == s1 == 0
    assert b0["x"].shape == (4, 3)
    # shards are disjoint parts of the same global batch
    rng = np.random.default_rng(np.random.SeedSequence([1, 0]))
    full = batch_fn(rng)["x"]
    np.testing.assert_array_equal(b0["x"], full[:4])
    np.testing.assert_array_equal(b1["x"], full[4:])
    # restart from step 5 replays the same stream
    l5 = ShardedLoader(batch_fn, 8, num_hosts=2, host_id=0, seed=1, start_step=5)
    s5, b5 = next(l5)
    assert s5 == 5
    rng5 = np.random.default_rng(np.random.SeedSequence([1, 5]))
    np.testing.assert_array_equal(b5["x"], batch_fn(rng5)["x"][:4])
    for l in (l0, l1, l5):
        l.close()


# ----------------------------------------------------------------------------
# serving
# ----------------------------------------------------------------------------

def test_pair_scorer_batching():
    from repro.data.pipeline import ByteTokenizer, pair_example
    from repro.serve.serve_loop import PairScorer

    tok = ByteTokenizer()
    cfg = get_smoke_config("qwen2-1.5b", vocab_size=tok.vocab_size, remat=False)
    params = init_params(cfg, jax.random.key(0))
    records = ["alpha corp", "alpha corp.", "zeta llc", "omega gmbh"]

    def tok_pair(pair):
        t, _ = pair_example(tok, records[pair[0]], records[pair[1]], None, 48)
        n = int((t != 0).sum())
        return t[:n]

    scorer = PairScorer(cfg, params, tok_pair, tok.YES, tok.NO, max_len=48,
                        batch_size=3)
    pairs = np.array([[0, 1], [0, 2], [2, 3], [1, 3], [0, 3]])
    p = scorer.score(pairs)
    assert p.shape == (5,)
    assert ((p >= 0) & (p <= 1)).all()
    # batch-size independence
    scorer2 = PairScorer(cfg, params, tok_pair, tok.YES, tok.NO, max_len=48,
                         batch_size=5)
    np.testing.assert_allclose(p, scorer2.score(pairs), atol=2e-2)


def test_continuous_batcher_matches_sequential_decode():
    from repro.serve.serve_loop import ContinuousBatcher, Request

    cfg = get_smoke_config("llama3.2-1b", remat=False)
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(7, 200, size=n).astype(np.int32) for n in (5, 3, 7)]
    cb = ContinuousBatcher(cfg, params, batch_size=4, max_len=64, eos_id=1)
    for i, pr in enumerate(prompts):
        cb.submit(Request(uid=i, prompt=pr, max_new_tokens=4))
    done = cb.run_until_done(max_steps=200)
    assert len(done) == 3
    for req in done:
        assert 1 <= len(req.out_tokens) <= 4
