"""Hypothesis property tests for the cascade's correction estimator.

The load-bearing claim in ``core/cascade.py`` is proxy-agnostic
unbiasedness: ``E[proxy_total_hat + correction_hat] = oracle_total`` for ANY
proxy, because both regime estimators are HT-unbiased and their samples are
disjoint.  We probe that over random proxy/oracle agreement patterns —
including the proxy==oracle and proxy==garbage extremes — plus the ledger
invariant that budget pacing under the two-stage schedule stays consistent
with the charged ledger, and graceful degradation: a useless proxy costs
variance, never validity — the CIs widen to cover the realised error
rather than silently going wrong.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # the seeded fallback below keeps the invariant tested
    HAS_HYPOTHESIS = False

from repro.core import Agg, ArrayOracle, BASConfig, Query, run_bas, run_bas_cascade
from repro.data import make_clustered_tables

CFG = BASConfig(n_bootstrap=100)

_DS = make_clustered_tables(56, 56, n_entities=84, noise=0.4, seed=17)
_TRUTH = float(_DS.truth.sum())


def _proxy_with_flip_rate(rate: float, seed: int) -> ArrayOracle:
    """Proxy = oracle truth with a ``rate`` fraction of labels flipped:
    rate 0 is the perfect-proxy extreme, rate ~1 the anti-correlated one,
    rate 0.5 pure garbage."""
    rng = np.random.default_rng(seed)
    labels = _DS.truth.astype(np.float64).copy()
    flip = rng.random(labels.shape) < rate
    labels[flip] = 1.0 - labels[flip]
    return ArrayOracle(labels)


def _run(seed: int, flip_rate: float, flip_seed: int, budget: int = 350):
    q = Query(spec=_DS.spec(), agg=Agg.COUNT, oracle=_DS.oracle(),
              budget=budget, proxy=_proxy_with_flip_rate(flip_rate, flip_seed))
    res = run_bas_cascade(q, CFG, seed=seed, path="dense")
    return q, res


def _check_ledger_pacing_and_result_sanity(flip_rate, flip_seed, seed):
    """For any proxy quality: the expensive ledger never exceeds the budget
    and matches the charged count exactly; the proxy ledger is unmetered;
    the result is finite with an ordered CI and in-range telemetry."""
    q, res = _run(seed, flip_rate, flip_seed)
    assert q.oracle.calls <= q.budget
    assert q.oracle.calls == q.oracle.charged
    assert res.oracle_calls == q.oracle.calls
    assert q.proxy.budget is None
    assert np.isfinite(res.estimate)
    assert res.ci.lo <= res.estimate <= res.ci.hi
    c = res.telemetry.cascade
    assert 0.0 <= c.disagreement_rate <= 1.0
    assert c.oracle_calls + 0 == q.oracle.calls
    assert c.proxy_calls == q.proxy.calls


if HAS_HYPOTHESIS:
    @given(
        flip_rate=st.one_of(st.just(0.0), st.just(1.0), st.floats(0.0, 1.0)),
        flip_seed=st.integers(0, 1000),
        seed=st.integers(0, 1000),
    )
    @settings(max_examples=12, deadline=None)
    def test_ledger_pacing_and_result_sanity(flip_rate, flip_seed, seed):
        _check_ledger_pacing_and_result_sanity(flip_rate, flip_seed, seed)
else:
    @pytest.mark.parametrize(
        "flip_rate,flip_seed,seed",
        [(0.0, 3, 0), (1.0, 5, 1), (0.37, 7, 2)],
    )
    def test_ledger_pacing_and_result_sanity(flip_rate, flip_seed, seed):
        _check_ledger_pacing_and_result_sanity(flip_rate, flip_seed, seed)


@pytest.mark.parametrize("flip_rate", [0.0, 0.5, 1.0])
def test_unbiased_over_seeds_at_proxy_extremes(flip_rate):
    """Mean estimate over replicates stays centred on truth whether the
    proxy is perfect (0.0), garbage (0.5), or anti-correlated (1.0)."""
    ests = [
        _run(seed, flip_rate, flip_seed=7)[1].estimate for seed in range(25)
    ]
    se = np.std(ests, ddof=1) / np.sqrt(len(ests))
    # 4-sigma band around truth, floored to 15% of truth for the near-zero
    # variance perfect-proxy case
    assert abs(np.mean(ests) - _TRUTH) < max(4.0 * se, 0.15 * _TRUTH)


def test_garbage_proxy_degrades_gracefully_to_bas_variance():
    """A pure-noise proxy must cost variance, not validity.  Uniformly
    flipped labels land disproportionately in low-sampling-weight strata,
    so the HT correction term gets genuinely heavy tails — RMSE can be far
    worse than plain BAS and that is expected, not a bug.  Graceful
    degradation means the machinery *reports* that variance instead of
    hiding it: CIs keep covering near nominal, the reported interval is
    wide enough to account for the realised error, and plain BAS on the
    same budget is untouched (the user always has the zero-proxy exit)."""
    n_rep, budget = 25, 350
    casc_err, widths, cover = [], [], 0
    for seed in range(n_rep):
        q, res = _run(seed, flip_rate=0.5, flip_seed=11, budget=budget)
        casc_err.append(res.estimate - _TRUTH)
        widths.append(res.ci.hi - res.ci.lo)
        cover += res.ci.contains(_TRUTH)
        qp = Query(spec=_DS.spec(), agg=Agg.COUNT, oracle=_DS.oracle(),
                   budget=budget)
        rp = run_bas(qp, CFG, seed=seed)
        assert rp.ci.contains(_TRUTH)        # plain path untouched by proxy
    assert cover / n_rep >= 0.80
    # realised error consistent with reported uncertainty: at nominal 0.95
    # the half-width is ~2 sigma, so RMSE ~ half-width / 2; allow 1x.
    rmse_c = float(np.sqrt(np.mean(np.square(casc_err))))
    assert rmse_c <= float(np.mean(widths)) / 2.0 * 2.0
