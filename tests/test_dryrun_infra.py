"""Dry-run infrastructure tests.

The full production-mesh dry-run (16x16 and 2x16x16 for all 40 cells) runs
via ``python -m repro.launch.dryrun --all --both-meshes`` (results under
experiments/dryrun).  Here we validate the machinery itself on a small
subprocess-isolated host mesh: sharding rules, lowering, the HLO analyzer's
trip-count expansion, and spec generation — without touching this process's
device count.
"""
import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int = 16) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        timeout=560,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharding_rules_divisibility_fallback():
    code = """
import jax
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 8), ("data", "model"))
from repro.launch.sharding import spec_for, TRAIN_RULES
# heads=12 not divisible by model=8 -> replicated; mlp=64 divisible -> sharded
s1 = spec_for(("batch", "seq", "heads"), (4, 16, 12), TRAIN_RULES, mesh)
s2 = spec_for(("batch", "seq", "mlp"), (4, 16, 64), TRAIN_RULES, mesh)
assert s1 == P(("pod", "data")[1:], None, None) or s1 == P("data", None, None), s1
assert s2[2] == "model", s2
# an axis is never used twice in one spec
s3 = spec_for(("mlp", "vocab"), (64, 64), TRAIN_RULES, mesh)
assert [a for a in s3 if a is not None].count("model") <= 1
print("OK")
"""
    assert "OK" in run_sub(code)


def test_small_mesh_cell_lowers_and_analyzer_expands():
    code = """
import jax, json
from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 2), ("data", "model"))
from repro.launch import cells as C
from repro.configs import get_smoke_config
import repro.launch.cells as cells_mod
# shrink the cell shapes so a smoke config can lower on 4 devices
cells_mod.SHAPES = {
    "train_4k": dict(kind="train", seq=32, batch=8),
    "decode_32k": dict(kind="decode", seq=64, batch=4),
}
import repro.configs as cfgs
orig = cfgs.get_config
cfgs.get_config = lambda name: get_smoke_config(name)
cell = C.build_cell("llama3.2-1b", "train_4k", mesh, num_microbatches=2)
lowered = C.lower_cell(cell, mesh)
compiled = lowered.compile()
from repro.roofline.hlo_analysis import analyze
cost = analyze(compiled.as_text(), cell.trip_hints)
assert cost.flops > 0 and cost.bytes > 0, (cost.flops, cost.bytes)
assert not cost.unresolved_whiles, cost.unresolved_whiles
# trip expansion: flops must scale ~ with layer count (2 layers vs 1)
cost1 = analyze(compiled.as_text(), dict(cell.trip_hints, layers_scan=1))
assert cost.flops > cost1.flops * 1.3
# decode cell lowers too
cell2 = C.build_cell("llama3.2-1b", "decode_32k", mesh)
C.lower_cell(cell2, mesh).compile()
print("OK")
"""
    assert "OK" in run_sub(code, devices=4)


def test_cell_supported_matrix():
    from repro.configs import ARCHS, get_config
    from repro.launch.cells import SHAPES, cell_supported

    total, skipped = 0, 0
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES:
            total += 1
            ok, why = cell_supported(cfg, shape)
            if not ok:
                skipped += 1
                assert shape == "long_500k"
                assert not cfg.supports_long_context
    assert total == 40
    assert skipped == 8  # exactly the pure full-attention archs on long_500k


def test_input_specs_no_allocation():
    """input_specs returns ShapeDtypeStructs (no device arrays)."""
    import jax

    from repro.configs import get_config
    from repro.launch.cells import input_specs
    from repro.launch.mesh import make_host_mesh
    from repro.launch.sharding import TRAIN_RULES

    mesh = make_host_mesh()
    specs = input_specs(get_config("whisper-medium"), "train_4k", mesh, TRAIN_RULES)
    assert set(specs) == {"tokens", "frames"}
    for leaf in jax.tree.leaves(specs):
        assert isinstance(leaf, jax.ShapeDtypeStruct)
    assert specs["tokens"].shape == (256, 4096)
    assert specs["frames"].shape == (256, 1500, 1024)


def test_baseline_dryrun_records_complete():
    """The committed dry-run sweep must cover every (arch x shape x mesh) cell
    with ok/skipped status and roofline terms."""
    out_dir = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")
    if not os.path.isdir(out_dir):
        pytest.skip("dry-run sweep not yet generated")
    recs = []
    for fn in os.listdir(out_dir):
        if fn.endswith(".json"):
            with open(os.path.join(out_dir, fn)) as f:
                recs.append(json.load(f))
    base = [r for r in recs if r.get("rules", "default") == "default"
            and not r.get("tag")]
    by_mesh = {}
    for r in base:
        by_mesh.setdefault(r["mesh"], []).append(r)
    for mesh, rs in by_mesh.items():
        assert len(rs) == 40, f"{mesh}: {len(rs)} records"
        assert sum(r["status"] == "ok" for r in rs) == 32
        assert sum(r["status"] == "skipped" for r in rs) == 8
        for r in rs:
            if r["status"] == "ok":
                assert r["roofline"]["bound_s"] > 0
                assert r["hlo_flops"] > 0
