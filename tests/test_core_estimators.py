"""Exact-expectation tests: on tiny spaces we *enumerate* the sampling
distribution, so unbiasedness checks are deterministic (no statistical flake).
"""
import numpy as np
import pytest

from repro.core.estimators import (
    BlockedRegime,
    StratumSample,
    combined_avg,
    combined_cdf_median,
    combined_count,
    combined_extreme,
    combined_sum,
    weighted_quantile,
)


def enumerate_expected_sum(o, g, w):
    """E[HT estimate with n=1 sample] = sum_s q_s * (g_s o_s / q_s) = SUM."""
    q = w / w.sum()
    est = 0.0
    for s in range(len(w)):
        samp = StratumSample(o=[o[s]], g=[g[s]], q=[q[s]], size=len(w))
        e, _ = combined_sum([samp], BlockedRegime(np.zeros(0), np.zeros(0)))
        est += q[s] * e
    return est


def test_ht_sum_exactly_unbiased_by_enumeration():
    rng = np.random.default_rng(0)
    o = (rng.random(12) < 0.4).astype(float)
    g = rng.lognormal(0, 1, 12)
    w = rng.random(12) + 0.05
    truth = float((g * o).sum())
    est = enumerate_expected_sum(o, g, w)
    np.testing.assert_allclose(est, truth, rtol=1e-12)


def test_ht_count_exactly_unbiased_by_enumeration():
    rng = np.random.default_rng(1)
    o = (rng.random(9) < 0.5).astype(float)
    w = rng.random(9) + 0.01
    q = w / w.sum()
    est = 0.0
    for s in range(9):
        samp = StratumSample(o=[o[s]], g=[1.0], q=[q[s]], size=9)
        e, _ = combined_count([samp], BlockedRegime(np.zeros(0), np.zeros(0)))
        est += q[s] * e
    np.testing.assert_allclose(est, o.sum(), rtol=1e-12)


def test_combined_adds_blocked_exactly():
    blocked = BlockedRegime(o=np.array([1.0, 0.0, 1.0]), g=np.array([2.0, 9.0, 3.0]))
    samp = StratumSample(o=[1.0, 1.0], g=[4.0, 4.0], q=[0.5, 0.5], size=2)
    s, _ = combined_sum([samp], blocked)
    c, _ = combined_count([samp], blocked)
    # blocked: sum=5, count=2; sampled stratum: each term 4/0.5=8, mean=8
    assert s == pytest.approx(5.0 + 8.0)
    assert c == pytest.approx(2.0 + 2.0)


def test_avg_ratio_and_bias_correction_direction():
    blocked = BlockedRegime(o=np.ones(4), g=np.array([1.0, 2.0, 3.0, 4.0]))
    est, var = combined_avg([], blocked, bias_correction=True)
    assert est == pytest.approx(2.5)
    assert var == 0.0


def test_extreme_observed():
    blocked = BlockedRegime(o=np.array([1.0, 1.0]), g=np.array([5.0, -2.0]))
    samp = StratumSample(o=[1.0, 0.0], g=[7.0, 100.0], q=[0.5, 0.5], size=2)
    assert combined_extreme([samp], blocked, "max") == 7.0
    assert combined_extreme([samp], blocked, "min") == -2.0
    # non-matching values (o=0) never contribute
    samp2 = StratumSample(o=[0.0], g=[1e9], q=[1.0], size=1)
    assert combined_extreme([samp2], blocked, "max") == 5.0


def test_median_exact_on_blocked_only():
    g = np.array([1.0, 2.0, 3.0, 4.0, 5.0])
    blocked = BlockedRegime(o=np.ones(5), g=g)
    med = combined_cdf_median([], blocked)
    assert med == 3.0


def test_median_ht_weighting():
    # two sampled positives with very different HT weights: the heavy one
    # dominates the CDF
    samp = StratumSample(o=[1.0, 1.0], g=[10.0, 20.0], q=[0.9, 0.01], size=100)
    med = combined_cdf_median([samp], BlockedRegime(np.zeros(0), np.zeros(0)))
    assert med == 20.0


def test_weighted_quantile_bounds():
    v = np.array([3.0, 1.0, 2.0])
    w = np.ones(3)
    qs = weighted_quantile(v, w, np.array([0.0, 0.5, 1.0]))
    assert qs[0] == 1.0 and qs[-1] == 3.0
    assert 1.0 <= qs[1] <= 3.0
