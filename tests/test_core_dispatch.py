"""Memory-aware dispatcher + dense/streaming statistical consistency.

The acceptance contract: ``method="auto"`` must route big joins through the
streaming path without ever allocating the flat N1*...*Nk weight array, and
the two paths must be statistically interchangeable on the same seeded query.
"""
import dataclasses
import tracemalloc

import numpy as np
import pytest

from repro.core import (
    Agg,
    BASConfig,
    Catalog,
    JoinMLEngine,
    Query,
    Table,
    choose_path,
    dense_weight_bytes,
    run_auto,
    run_bas,
    run_bas_streaming,
)
from repro.data import make_chain_dataset, make_clustered_tables


def small_cap(cap_bytes: int) -> BASConfig:
    return dataclasses.replace(BASConfig(), max_dense_weight_bytes=cap_bytes)


def test_choose_path_threshold():
    ds = make_clustered_tables(100, 100, n_entities=100, noise=0.4, seed=0)
    spec = ds.spec()
    assert dense_weight_bytes(spec) == 100 * 100 * 8
    assert choose_path(spec) == "dense"  # default cap is 256 MiB
    assert choose_path(spec, small_cap(100 * 100 * 8 - 1)) == "streaming"
    assert choose_path(spec, small_cap(100 * 100 * 8)) == "dense"


def test_auto_dispatch_recorded_in_detail():
    ds = make_clustered_tables(120, 120, n_entities=150, noise=0.4, seed=3)
    q = Query(spec=ds.spec(), agg=Agg.COUNT, oracle=ds.oracle(), budget=2000)
    res = run_auto(q, seed=0)
    assert res.detail["dispatch"]["path"] == "dense"
    assert res.detail["mode"] == "bas"
    q = Query(spec=ds.spec(), agg=Agg.COUNT, oracle=ds.oracle(), budget=2000)
    res = run_auto(q, small_cap(1024), seed=0)
    assert res.detail["dispatch"]["path"] == "streaming"
    assert res.detail["mode"] == "bas_streaming"


def test_dense_streaming_consistent_two_way():
    ds = make_clustered_tables(250, 250, n_entities=400, noise=0.4, seed=7)
    truth = float(ds.truth.sum())
    errs_d, errs_s, cover_d, cover_s = [], [], 0, 0
    n_rep = 3
    for seed in range(n_rep):
        qd = Query(spec=ds.spec(), agg=Agg.COUNT, oracle=ds.oracle(), budget=5000)
        rd = run_bas(qd, seed=seed)
        qs = Query(spec=ds.spec(), agg=Agg.COUNT, oracle=ds.oracle(), budget=5000)
        rs = run_bas_streaming(qs, seed=seed)
        assert rs.oracle_calls <= 5000
        errs_d.append(abs(rd.estimate - truth) / truth)
        errs_s.append(abs(rs.estimate - truth) / truth)
        cover_d += rd.ci.contains(truth)
        cover_s += rs.ci.contains(truth)
    assert np.mean(errs_d) < 0.4
    assert np.mean(errs_s) < max(2.5 * np.mean(errs_d), 0.4)
    assert cover_d >= n_rep - 1 and cover_s >= n_rep - 1


def test_dense_streaming_consistent_three_way():
    ds = make_chain_dataset([90, 80, 70], n_entities=40, noise=0.35, seed=5)
    truth = float(ds.truth_flat().sum())
    assert truth > 0
    qd = Query(spec=ds.spec(), agg=Agg.COUNT, oracle=ds.oracle(), budget=8000)
    rd = run_bas(qd, seed=0)
    qs = Query(spec=ds.spec(), agg=Agg.COUNT, oracle=ds.oracle(), budget=8000)
    rs = run_bas_streaming(qs, seed=0)
    assert rs.oracle_calls <= 8000
    assert abs(rd.estimate - truth) / truth < 0.5
    assert abs(rs.estimate - truth) / truth < 0.5
    # CIs of the two paths must overlap (same design, same data)
    assert rs.ci.lo <= rd.ci.hi and rd.ci.lo <= rs.ci.hi


@pytest.mark.slow
def test_streaming_three_way_never_materialises_flat_weights(monkeypatch):
    """Acceptance: auto on a 160^3 chain (flat weights would be ~33 MB) runs
    streaming under a 24 MB python-heap peak and never calls the dense
    chain_weights materialiser."""
    import repro.core.bas as bas_mod

    ds = make_chain_dataset([160, 160, 160], n_entities=60, noise=0.35, seed=9)
    spec = ds.spec()
    dense_bytes = dense_weight_bytes(spec)
    assert dense_bytes == 160**3 * 8  # ~33 MB

    def boom(*a, **k):
        raise AssertionError("dense chain_weights materialised on streaming path")

    monkeypatch.setattr(bas_mod, "chain_weights", boom)
    truth = float(ds.truth_flat().sum())
    cfg = small_cap(8 * 2**20)  # 8 MiB cap << 33 MB footprint
    q = Query(spec=spec, agg=Agg.COUNT, oracle=ds.oracle(), budget=6000)
    tracemalloc.start()
    res = run_auto(q, cfg, seed=0)
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    assert res.detail["dispatch"]["path"] == "streaming"
    assert res.detail["dispatch"]["dense_weight_bytes"] == dense_bytes
    assert peak < 24 * 2**20, f"python-heap peak {peak/2**20:.1f} MiB"
    assert res.oracle_calls <= 6000
    if truth > 0:
        assert abs(res.estimate - truth) / truth < 1.0


def test_streaming_median_min_max_supported():
    """The shared pipeline gives the streaming path the dense extensions."""
    ds = make_clustered_tables(150, 150, n_entities=200, noise=0.4, seed=11)
    g_col = ds.columns1["value"]
    g = lambda idx: g_col[idx[:, 0]]  # noqa: E731
    vals = np.broadcast_to(g_col[:, None], ds.truth.shape)[ds.truth > 0]
    q = Query(spec=ds.spec(), agg=Agg.MAX, oracle=ds.oracle(), budget=4000, g=g)
    q.g_bounds = (float(g_col.min()), float(g_col.max()))
    r = run_bas_streaming(q, seed=0)
    assert r.estimate <= vals.max() + 1e-9
    assert r.ci.hi >= vals.max()
    q = Query(spec=ds.spec(), agg=Agg.MEDIAN, oracle=ds.oracle(), budget=4000, g=g)
    r = run_bas_streaming(q, seed=0)
    assert np.quantile(vals, 0.02) <= r.estimate <= np.quantile(vals, 0.98)


@pytest.fixture(scope="module")
def chain_engine():
    ds = make_chain_dataset([80, 70, 60], n_entities=35, noise=0.35, seed=21)
    cat = Catalog()
    for name, emb in zip(("a", "b", "c"), ds.embeddings):
        cat.register(Table(name, emb))
    return JoinMLEngine(cat, lambda nl, names: ds.oracle()), ds


def test_engine_auto_three_way(chain_engine):
    eng, ds = chain_engine
    truth = float(ds.truth_flat().sum())
    res = eng.execute(
        "SELECT COUNT(*) FROM a JOIN b JOIN c ON NL('same entity') "
        "ORACLE BUDGET 6000 WITH PROBABILITY 0.95"
    )
    assert res.detail["dispatch"]["path"] == "dense"  # 336k tuples fit
    assert np.isfinite(res.estimate)
    eng_small = JoinMLEngine(eng.catalog, eng.oracle_factory, cfg=small_cap(2**20))
    res = eng_small.execute(
        "SELECT COUNT(*) FROM a JOIN b JOIN c ON NL('same entity') "
        "ORACLE BUDGET 6000 WITH PROBABILITY 0.95"
    )
    assert res.detail["dispatch"]["path"] == "streaming"
    assert res.detail["mode"] == "bas_streaming"
    if truth > 0:
        assert abs(res.estimate - truth) / truth < 1.0


def test_engine_explicit_streaming_method(chain_engine):
    eng, ds = chain_engine
    res = eng.execute(
        "SELECT COUNT(*) FROM a JOIN b JOIN c ON NL('same entity') "
        "ORACLE BUDGET 5000 WITH PROBABILITY 0.9",
        method="bas-streaming",
    )
    assert res.detail["mode"] == "bas_streaming"
    assert res.oracle_calls <= 5000
