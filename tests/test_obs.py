"""The observability layer: trackers, the typed telemetry tree, and the
unified ``snapshot()`` stats surface.

Contracts under test: :class:`StreamingHistogram` quantiles reflect the
*recent* window while count/mean stay lifetime; every tracker folds counters,
gauges, and observations into one flat ``{dotted.name: float}`` snapshot;
``JsonlTracker`` additionally writes one parseable JSON line per signal;
``QueryTelemetry`` round-trips every legacy ``detail`` dict bit-for-bit
through ``from_detail``/``as_detail``; ``QueryResult.detail`` survives as a
deprecation-warned write-through view; and service + stores expose one merged
``snapshot()`` namespace.
"""
import json
import warnings

import numpy as np
import pytest

from repro.core import FnOracle, IndexStore, QueryResult
from repro.core.types import ConfidenceInterval
from repro.obs import (
    InMemoryTracker,
    JsonlTracker,
    NoopTracker,
    QueryTelemetry,
    StreamingHistogram,
    Tracker,
    make_tracker,
    merge_snapshots,
)
from repro.serve.label_store import LabelStore
from repro.serve.oracle_service import OracleService


# ----------------------------------------------------------------------------
# StreamingHistogram
# ----------------------------------------------------------------------------

def test_histogram_quantiles_track_recent_window_only():
    """Quantiles come from the last-N ring, lifetime stats from everything:
    after 1000 observations with window=100, p50 sits in the last hundred
    values while count/mean/max still cover all thousand."""
    h = StreamingHistogram(window=100)
    for v in range(1, 1001):                       # 1, 2, ..., 1000
        h.observe(float(v))
    assert h.count == 1000
    assert h.mean == pytest.approx(500.5)
    assert h.vmin == 1.0 and h.vmax == 1000.0
    assert 901.0 <= h.quantile(0.5) <= 1000.0      # recent window only
    assert h.quantile(0.0) == 901.0
    assert h.quantile(1.0) == 1000.0
    assert h.recent_mean() == pytest.approx(950.5)


def test_histogram_snapshot_names_and_empty():
    h = StreamingHistogram(window=8)
    assert h.snapshot("x") == {}                   # nothing observed: no keys
    h.observe(2.0)
    h.observe(4.0)
    snap = h.snapshot("service.window.assembly_ms")
    assert set(snap) == {
        "service.window.assembly_ms.count",
        "service.window.assembly_ms.mean",
        "service.window.assembly_ms.p50",
        "service.window.assembly_ms.p99",
        "service.window.assembly_ms.max",
    }
    assert snap["service.window.assembly_ms.count"] == 2.0
    assert snap["service.window.assembly_ms.mean"] == 3.0
    assert snap["service.window.assembly_ms.max"] == 4.0
    with pytest.raises(ValueError):
        StreamingHistogram(window=0)


# ----------------------------------------------------------------------------
# trackers
# ----------------------------------------------------------------------------

def test_in_memory_tracker_snapshot_is_flat_dotted_floats():
    t = InMemoryTracker()
    assert isinstance(t, Tracker)                  # satisfies the protocol
    t.count("transport.reconnects")
    t.count("transport.reconnects", 2)
    t.gauge("transport.inflight", 5)
    for ms in (1.0, 2.0, 3.0, 4.0):
        t.observe("transport.rtt_ms", ms)
    t.event("service.worker.dead", worker="h:1")
    snap = t.snapshot()
    assert snap["transport.reconnects"] == 3
    assert snap["transport.inflight"] == 5.0
    assert snap["transport.rtt_ms.count"] == 4.0
    assert snap["transport.rtt_ms.mean"] == 2.5
    assert snap["service.worker.dead.events"] == 1.0
    assert all(isinstance(v, float) or isinstance(v, int)
               for v in snap.values())
    assert t.histogram("transport.rtt_ms").count == 4
    assert t.histogram("never.observed") is None


def test_noop_tracker_is_protocol_and_empty():
    t = NoopTracker()
    assert isinstance(t, Tracker)
    t.count("a")
    t.gauge("b", 1.0)
    t.observe("c", 2.0)
    t.event("d", x=1)
    assert t.snapshot() == {}
    t.close()


def test_jsonl_tracker_writes_parseable_lines(tmp_path):
    path = tmp_path / "tracker.jsonl"
    t = JsonlTracker(path, flush_every=1)
    t.count("service.windows")
    t.observe("service.shard.local_ms", 1.5)
    t.event("service.worker.rejoined", worker="h:2")
    snap = t.snapshot()                            # in-memory view also live
    assert snap["service.windows"] == 1
    t.close()
    lines = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert [r["kind"] for r in lines] == ["count", "observe", "event"]
    assert lines[1]["name"] == "service.shard.local_ms"
    assert lines[1]["value"] == 1.5
    assert lines[2]["worker"] == "h:2"
    assert all("ts" in r for r in lines)
    t.count("after.close")                         # silently dropped, no raise


def test_make_tracker_factory(tmp_path):
    assert isinstance(make_tracker("none"), NoopTracker)
    assert isinstance(make_tracker(None), NoopTracker)
    assert isinstance(make_tracker("memory"), InMemoryTracker)
    jt = make_tracker("jsonl", path=tmp_path / "t.jsonl")
    assert isinstance(jt, JsonlTracker)
    jt.close()
    with pytest.raises(ValueError):
        make_tracker("jsonl")                      # needs a path
    with pytest.raises(ValueError):
        make_tracker("statsd")


def test_merge_snapshots_later_parts_win():
    assert merge_snapshots({"a": 1.0}, None, {"a": 2.0, "b": 3.0}) == {
        "a": 2.0, "b": 3.0,
    }


# ----------------------------------------------------------------------------
# QueryTelemetry <-> legacy detail dict
# ----------------------------------------------------------------------------

_LEGACY_DETAIL = {
    "mode": "bas",
    "beta": [0.5, 0.5],
    "num_strata": 4,
    "stratum_sizes": [10, 20, 30, 40],
    "pilot_n": [5, 5, 5, 5],
    "est_mse": 0.002,
    "stratify": {
        "path": "sweep",
        "index_hit": True,
        "index_version": 3,
        "delta_blocks": 2,
        "sweep_tiles": 7,
    },
    "timings": {"stratify_s": 0.1, "sample_s": 0.2},
    "oracle": {
        "calls": 100,
        "requests": 150,
        "batches": 4,
        "charged": 90,
        "store_hits": 10,
        "store_charge_saved": 10,
        "dedup_ratio": 0.33,
    },
    "dispatch": {
        "path": "sweep",
        "dense_weight_bytes": 1024,
        "max_dense_weight_bytes": 4096,
        "n_tuples": 10000,
        "sweep": True,
        "sweep_precision": "bf16",
        "index_store": True,
    },
}


def test_telemetry_round_trips_legacy_detail_exactly():
    t = QueryTelemetry.from_detail(_LEGACY_DETAIL)
    assert t.mode == "bas"
    assert t.oracle.calls == 100
    assert t.store.hits == 10                      # split out of oracle stats
    assert t.index.hit is True and t.index.version == 3
    assert t.index.build_ms is None                # omitted key stays omitted
    assert t.stratify.path == "sweep"
    assert t.stratify.extra == {"sweep_tiles": 7}  # producer payload kept
    assert t.dispatch.sweep_precision == "bf16"
    assert t.as_detail() == _LEGACY_DETAIL


def test_telemetry_round_trips_sparse_details():
    for d in ({}, {"mode": "exact"},
              {"mode": "wwj", "weights": [1, 2]},
              {"oracle": {"calls": 1, "requests": 1, "batches": 1,
                          "charged": 1, "dedup_ratio": 0.0}},
              {"stratify": {"path": "dense-sort"}}):
        assert QueryTelemetry.from_detail(d).as_detail() == d


def test_query_result_detail_is_deprecated_write_through_view():
    res = QueryResult(1.0, ConfidenceInterval(0.5, 1.5, 0.95), 10,
                      detail=dict(_LEGACY_DETAIL))
    assert res.telemetry.oracle.requests == 150

    import repro.obs.telemetry as telem
    telem._warned = False                          # re-arm the one-shot warn
    with pytest.warns(DeprecationWarning):
        view = res.detail
    assert view["mode"] == "bas"
    assert view["oracle"]["calls"] == 100
    assert "dispatch" in view and "nonexistent" not in view
    assert dict(view) == _LEGACY_DETAIL

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        res.detail["mode"] = "exact"               # top-level write-through
        assert res.telemetry.mode == "exact"
        res.detail["custom"] = 42                  # unknown keys -> extra
        assert res.telemetry.extra["custom"] == 42
        del res.detail["stratify"]
        assert res.telemetry.stratify is None and res.telemetry.index is None
        with pytest.raises(KeyError):
            del res.detail["never-there"]


def test_query_result_rejects_detail_and_telemetry_together():
    t = QueryTelemetry(mode="bas")
    with pytest.raises(TypeError):
        QueryResult(1.0, ConfidenceInterval(0.5, 1.5, 0.95), 1,
                    detail={"mode": "bas"}, telemetry=t)
    res = QueryResult(1.0, ConfidenceInterval(0.5, 1.5, 0.95), 1, telemetry=t)
    assert res.telemetry is t


# ----------------------------------------------------------------------------
# the unified snapshot() surface
# ----------------------------------------------------------------------------

def test_store_snapshots_use_dotted_namespaces(tmp_path):
    ls = LabelStore()
    snap = ls.snapshot()
    assert "label_store.hit_rate" in snap
    assert "label_store.entries" in snap
    assert all(k.startswith("label_store.") for k in snap)
    assert all(isinstance(v, float) for v in snap.values())

    ix = IndexStore(root=str(tmp_path))
    snap = ix.snapshot()
    assert "index_store.warm_hits" in snap
    assert all(k.startswith("index_store.") for k in snap)


def test_service_snapshot_merges_tracker_stores_and_counters():
    tracker = InMemoryTracker()
    with OracleService(max_wait_ms=1.0, label_store=LabelStore(),
                       tracker=tracker) as svc:
        o = FnOracle(lambda idx: (idx.sum(axis=1) % 2).astype(np.float64))
        o.bind_sizes((100, 100))
        svc.attach(o)
        o.label(np.array([[1, 2], [3, 4], [3, 4]]))
        svc.detach(o)
        snap = svc.snapshot()
    assert snap["service.windows"] >= 1.0
    assert snap["service.segments"] >= 1.0
    assert 0.0 < snap["service.window.fill_ratio_recent"] <= 1.0
    assert "service.window.dedup_ratio" in snap
    assert "label_store.hit_rate" in snap          # store merged in
    assert "service.window.assembly_ms.p50" in snap  # tracker series merged
    assert "service.shard.local_ms.p99" in snap
    assert "service.class.default.flush_ms.count" in snap
    assert all(isinstance(v, float) for v in snap.values())


def test_noop_tracker_service_snapshot_still_has_base_keys():
    """snapshot() works without instrumentation: base counters and store
    namespaces are present even when the tracker records nothing."""
    with OracleService(max_wait_ms=1.0) as svc:
        snap = svc.snapshot()
    assert snap["service.windows"] == 0.0
    assert snap["service.admission.rejected"] == 0.0
    assert snap["service.worker.live"] == 0.0
    assert not any(k.endswith(".p50") for k in snap)


def test_launcher_prints_service_class_histograms(capsys):
    """The launcher shutdown print surfaces one line per deadline/query
    class, fed from the ``service.class.*`` snapshot keys an attached class
    generates (flush-latency percentiles + the per-class admission EWMA)."""
    from repro.launch.serve import _print_service_stats
    from repro.serve.oracle_service import OracleService

    tracker = InMemoryTracker()
    with OracleService(max_wait_ms=1.0, tracker=tracker) as svc:
        o = FnOracle(lambda idx: np.ones(len(idx), np.float64))
        o.bind_sizes((100, 100))
        svc.attach(o, deadline_ms=60_000.0, query_class="tight")
        o.label(np.array([[1, 2], [3, 4]]))
        snap = svc.snapshot()

    # the attached class produced its snapshot keys...
    assert "service.class.tight.flush_ms.p50" in snap
    assert "service.class.tight.flush_ms.p99" in snap
    assert snap["service.class.tight.rate_rows_per_s"] > 0.0
    # ...and the shutdown print renders them
    _print_service_stats("service", snap)
    out = capsys.readouterr().out
    assert "class 'tight':" in out
    assert "p50=" in out and "p99=" in out and "rate=" in out


# ----------------------------------------------------------------------------
# OpenMetrics exporter: snapshot() dicts -> Prometheus scrape surface
# ----------------------------------------------------------------------------

def test_render_openmetrics_contract():
    """Rendering mangles dotted names, types every sample as a gauge, drops
    non-finite values, and terminates with # EOF."""
    from repro.obs import render_openmetrics

    snap = {
        "service.window.fill_ratio": 0.25,
        "service.shard.rate.127.0.0.1:9000": 1234.5,
        "label_store.hits": 7,
        "bad.value": float("nan"),
        "9starts.with.digit": 1.0,
    }
    body = render_openmetrics(snap)
    lines = body.splitlines()
    assert lines[-1] == "# EOF" and body.endswith("\n")
    assert "# TYPE repro_service_window_fill_ratio gauge" in lines
    assert "repro_service_window_fill_ratio 0.25" in lines
    # ':' survives (legal in prometheus names); '.' does not
    assert "repro_service_shard_rate_127_0_0_1:9000 1234.5" in lines
    assert "repro_label_store_hits 7.0" in lines
    assert not any("bad_value" in ln for ln in lines)       # NaN dropped
    assert "_9starts_with_digit 1.0" in [
        ln for ln in lines if "digit" in ln and "TYPE" not in ln
    ][0]
    # every sample line is parseable as "name value"
    for ln in lines:
        if not ln.startswith("#"):
            name, val = ln.split(" ")
            float(val)


def test_metrics_exporter_http_roundtrip():
    """The /metrics endpoint serves the merged live snapshots with the
    OpenMetrics content type; a failing source is skipped, not fatal."""
    import urllib.request

    from repro.obs import MetricsExporter

    tracker = InMemoryTracker()
    tracker.count("scrapes", 3)

    def broken():
        raise RuntimeError("wedged store")

    with OracleService(max_wait_ms=1.0, tracker=tracker) as svc:
        o = FnOracle(lambda idx: np.ones(len(idx), np.float64))
        o.bind_sizes((100, 100))
        svc.attach(o)
        o.label(np.array([[1, 2], [3, 4]]))
        with MetricsExporter([svc.snapshot, broken], port=0) as exp:
            host, port = exp.address
            with urllib.request.urlopen(
                f"http://{host}:{port}/metrics", timeout=10
            ) as resp:
                assert resp.status == 200
                ctype = resp.headers["Content-Type"]
                body = resp.read().decode("utf-8")
        assert ctype.startswith("application/openmetrics-text")
        assert body.rstrip().endswith("# EOF")
        assert "repro_service_rows_labelled 2.0" in body
        assert "repro_scrapes 3.0" in body
        svc.detach(o)


def test_metrics_exporter_404_off_path():
    from repro.obs import MetricsExporter
    import urllib.error
    import urllib.request

    with MetricsExporter([lambda: {"x": 1.0}], port=0) as exp:
        host, port = exp.address
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(f"http://{host}:{port}/nope", timeout=10)
