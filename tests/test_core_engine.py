import numpy as np
import pytest

from repro.core import Agg, ArrayOracle, Catalog, JoinMLEngine, Table, parse_query
from repro.data import make_clustered_tables


def test_parse_paper_examples():
    pq = parse_query(
        "SELECT COUNT(*) FROM article JOIN db ON NL('{article.sentence} is "
        "paraphrased from {db.sentence}.') ORACLE BUDGET 1000000 WITH PROBABILITY 0.95"
    )
    assert pq.agg is Agg.COUNT
    assert pq.table_names == ["article", "db"]
    assert pq.budget == 1000000
    assert pq.confidence == 0.95

    pq = parse_query(
        "SELECT AVG(video1.ts - video2.ts) FROM video1 JOIN video2 "
        "ON NL('Frame {video1.frame} and Frame {video2.frame} contains the same car.')"
    )
    assert pq.agg is Agg.AVG
    assert pq.expr == "video1.ts - video2.ts"

    pq = parse_query(
        "SELECT SUM(a.n_answers) FROM a JOIN b JOIN c ON NL('x') ORACLE BUDGET 5"
    )
    assert pq.table_names == ["a", "b", "c"]


def test_parse_rejects_garbage():
    with pytest.raises(ValueError):
        parse_query("SELECT FROM x")


@pytest.fixture(scope="module")
def engine():
    ds = make_clustered_tables(150, 150, n_entities=200, noise=0.35, seed=31)
    cat = Catalog()
    cat.register(Table("video1", ds.emb1, ds.columns1))
    cat.register(Table("video2", ds.emb2, ds.columns2))
    truth = ds.truth

    def oracle_factory(nl, names):
        return ArrayOracle(truth)

    return JoinMLEngine(cat, oracle_factory), ds


def test_engine_count(engine):
    eng, ds = engine
    truth = float(ds.truth.sum())
    res = eng.execute(
        "SELECT COUNT(*) FROM video1 JOIN video2 ON NL('same car') "
        "ORACLE BUDGET 4000 WITH PROBABILITY 0.95"
    )
    assert abs(res.estimate - truth) / max(truth, 1) < 0.6
    assert res.oracle_calls <= 4000


def test_engine_avg_expr(engine):
    eng, ds = engine
    res = eng.execute(
        "SELECT AVG(video2.ts - video1.ts) FROM video1 JOIN video2 "
        "ON NL('same car') ORACLE BUDGET 4000 WITH PROBABILITY 0.95"
    )
    m = ds.truth > 0
    diffs = (ds.columns2["ts"][None, :] - ds.columns1["ts"][:, None])[m]
    assert np.isfinite(res.estimate)
    assert abs(res.estimate - diffs.mean()) < 4 * diffs.std() / np.sqrt(max(m.sum(), 1)) + 0.25 * abs(diffs.mean()) + 50


def test_engine_all_methods(engine):
    eng, ds = engine
    for method in ("bas", "wwj", "uniform", "abae", "blazeit"):
        res = eng.execute(
            "SELECT COUNT(*) FROM video1 JOIN video2 ON NL('same car') "
            "ORACLE BUDGET 2000 WITH PROBABILITY 0.9",
            method=method,
        )
        assert np.isfinite(res.estimate)
