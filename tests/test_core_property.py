"""Hypothesis property tests on the statistical engine's invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.core.allocate import argmin_beta, budget_assign, estimate_mse
from repro.core.estimators import BlockedRegime, StratumSample, combined_count, combined_sum
from repro.core.similarity import flat_to_tuples, tuples_to_flat
from repro.core.stratify import stratify_dense, threshold_for_top_m
from repro.core.types import BASConfig

CFG = BASConfig()

pos_floats = st.floats(min_value=1e-3, max_value=1e3, allow_nan=False)


@given(
    w=hnp.arrays(np.float64, st.integers(10, 200), elements=pos_floats),
    alpha=st.floats(0.05, 0.9),
    budget=st.integers(10, 500),
)
@settings(max_examples=40, deadline=None)
def test_stratify_partition_properties(w, alpha, budget):
    strat = stratify_dense(w, alpha, budget, CFG)
    sizes = strat.stratum_sizes()
    assert sizes.sum() == len(w)
    assert (sizes >= 0).all()
    m = strat.blocking_regime_size()
    assert m == min(int(round(alpha * budget)), len(w))
    assert len(np.unique(strat.order)) == len(strat.order)  # no duplicates
    if m > 1:
        ow = w[strat.order]
        assert np.all(np.diff(ow) <= 1e-9)


@given(
    k=st.integers(1, 8),
    seed=st.integers(0, 10_000),
    b2=st.integers(50, 5000),
)
@settings(max_examples=40, deadline=None)
def test_budget_assign_conservation(k, seed, b2):
    rng = np.random.default_rng(seed)
    wsum = rng.random(k + 1) + 1e-3
    sizes = rng.integers(1, 100, size=k + 1)
    mask = np.zeros(k + 1, bool)
    mask[1:] = rng.random(k) < 0.4
    n = budget_assign(b2, wsum, sizes, mask)
    # blocked strata get exactly their size
    assert np.all(n[mask] == sizes[mask])
    # sampled budget = b2 - blocked cost (floored at 0)
    rem = max(b2 - sizes[mask].sum(), 0)
    np.testing.assert_allclose(n[~mask].sum(), rem, rtol=1e-9, atol=1e-9)
    assert (n >= 0).all()


@given(k=st.integers(1, 6), seed=st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_argmin_beta_never_worse_than_empty(k, seed):
    rng = np.random.default_rng(seed)
    sigma2 = rng.lognormal(0, 1.5, k + 1)
    wsum = rng.random(k + 1) + 1e-2
    sizes = rng.integers(10, 80, size=k + 1)
    b2 = int(sizes.sum())
    alloc = argmin_beta(sigma2, wsum, sizes, b2, exact_max_k=16)
    empty = estimate_mse(sigma2, wsum, sizes, np.zeros(k + 1, bool), b2)
    assert alloc.est_mse <= empty + 1e-9


@given(
    st.integers(1, 5).flatmap(
        lambda k: st.tuples(
            st.just(tuple(np.random.default_rng(k).integers(2, 9, size=k))),
            st.integers(0, 10_000),
        )
    )
)
@settings(max_examples=30, deadline=None)
def test_flat_tuple_roundtrip_random(args):
    sizes, seed = args
    n_total = int(np.prod(sizes))
    rng = np.random.default_rng(seed)
    flat = rng.integers(0, n_total, size=20)
    tup = flat_to_tuples(flat, sizes)
    assert (tup < np.array(sizes)).all()
    np.testing.assert_array_equal(tuples_to_flat(tup, sizes), flat)


@given(seed=st.integers(0, 10_000), n=st.integers(2, 50))
@settings(max_examples=30, deadline=None)
def test_ht_enumeration_unbiased(seed, n):
    """Exact unbiasedness by enumeration for arbitrary weights/values."""
    rng = np.random.default_rng(seed)
    o = (rng.random(n) < 0.5).astype(float)
    g = rng.lognormal(0, 1, n)
    w = rng.random(n) + 1e-3
    q = w / w.sum()
    expect_sum = 0.0
    expect_cnt = 0.0
    for s in range(n):
        samp = StratumSample(o=[o[s]], g=[g[s]], q=[q[s]], size=n)
        es, _ = combined_sum([samp], BlockedRegime(np.zeros(0), np.zeros(0)))
        ec, _ = combined_count([samp], BlockedRegime(np.zeros(0), np.zeros(0)))
        expect_sum += q[s] * es
        expect_cnt += q[s] * ec
    np.testing.assert_allclose(expect_sum, (g * o).sum(), rtol=1e-9)
    np.testing.assert_allclose(expect_cnt, o.sum(), rtol=1e-9)


@given(
    counts=hnp.arrays(np.int64, st.integers(4, 64), elements=st.integers(0, 1000)),
    m_frac=st.floats(0.01, 0.99),
)
@settings(max_examples=40, deadline=None)
def test_histogram_threshold_conservative(counts, m_frac):
    total = int(counts.sum())
    if total == 0:
        return
    edges = np.linspace(0, 1, len(counts) + 1)
    m = max(int(m_frac * total), 1)
    thr = threshold_for_top_m(counts, edges, m)
    # mass at-or-above the threshold bin covers at least m
    bin_idx = int(np.searchsorted(edges, thr, side="right")) - 1
    bin_idx = max(min(bin_idx, len(counts) - 1), 0)
    assert counts[bin_idx:].sum() >= m


@given(seed=st.integers(0, 10_000), n=st.integers(4, 40),
       mix=st.floats(0.05, 0.5))
@settings(max_examples=30, deadline=None)
def test_defensive_mix_bounds_ht_weights_and_stays_unbiased(seed, n, mix):
    """Defensive mixture: (a) HT terms bounded by |support|/mix; (b) the
    estimator stays exactly unbiased (enumeration over the proposal)."""
    from repro.core.wander import flat_sample

    rng = np.random.default_rng(seed)
    w = rng.random(n) ** 6 + 1e-9          # heavily skewed weights
    v = rng.lognormal(0, 1, n)
    p = w / w.sum()
    q = (1 - mix) * p + mix / n
    # (a) bound: 1/q <= n/mix
    assert (1.0 / q).max() <= n / mix + 1e-6
    # (b) exact unbiasedness by enumeration: sum_s q_s * v_s/q_s = sum v
    np.testing.assert_allclose((q * (v / q)).sum(), v.sum(), rtol=1e-9)
    # and flat_sample really samples from q (probability bookkeeping)
    pos, q_ret = flat_sample(w, 64, np.random.default_rng(seed), defensive_mix=mix)
    np.testing.assert_allclose(q_ret, q[pos], rtol=1e-9)


def test_streaming_rejection_probabilities_exact_by_enumeration():
    """The walk+rejection D_0 sampler's claimed probabilities sum to
    (1 - P(top)) over D_0 — so HT with q = p/(1-P(top)) is exactly unbiased."""
    from repro.core.similarity import normalize, pair_weights
    from repro.core.types import BASConfig

    rng = np.random.default_rng(3)
    e1 = normalize(rng.standard_normal((6, 8)))
    e2 = normalize(rng.standard_normal((5, 8)))
    cfg = BASConfig()
    w = pair_weights(e1, e2, cfg.weight_exponent, cfg.weight_floor)
    n1, n2 = w.shape
    row_sums = w.sum(axis=1)
    p_full = (1.0 / n1) * w / row_sums[:, None]     # the walk distribution
    np.testing.assert_allclose(p_full.sum(), 1.0, rtol=1e-9)
    top = {0 * n2 + 1, 3 * n2 + 2, 5 * n2 + 4}      # arbitrary blocking set
    p_top = sum(p_full[f // n2, f % n2] for f in top)
    d0 = [f for f in range(n1 * n2) if f not in top]
    q = np.array([p_full[f // n2, f % n2] for f in d0]) / (1.0 - p_top)
    np.testing.assert_allclose(q.sum(), 1.0, rtol=1e-9)
