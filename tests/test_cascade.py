"""Multi-fidelity cascade (``core/cascade.py``): wiring and ledger contracts.

Statistical validity lives in ``tests/test_guarantees.py`` (coverage) and
``tests/test_cascade_property.py`` (unbiasedness / degradation under random
proxy quality).  Here we pin the deterministic contracts: the §2 budget binds
only the expensive oracle, telemetry reports both stages, dispatch and the
engine route the cascade, non-linear aggregates fall back to plain BAS, and
execution through an :class:`OracleService` is bit-identical to serial while
proxy and oracle traffic super-batch under distinct service groups.
"""
import numpy as np
import pytest

from repro.core import (
    Agg,
    ArrayOracle,
    BASConfig,
    Catalog,
    JoinMLEngine,
    Query,
    Table,
    run_auto,
    run_bas_cascade,
    similarity_proxy,
)
from repro.data import make_clustered_tables
from repro.obs import InMemoryTracker
from repro.serve.oracle_service import OracleService, serve_queries


@pytest.fixture(scope="module")
def ds():
    return make_clustered_tables(80, 80, n_entities=120, noise=0.4, seed=3)


def _mk_query(ds, budget=600, proxy=None, agg=Agg.COUNT, **kw):
    return Query(spec=ds.spec(), agg=agg, oracle=ds.oracle(), budget=budget,
                 proxy=proxy, **kw)


def test_perfect_proxy_reports_zero_disagreement(ds):
    """proxy == oracle: every correction label is 0, so the pilot measures
    zero disagreement and the estimate still lands (proxy regime carries it)."""
    truth = float(ds.truth.sum())
    q = _mk_query(ds, proxy=ArrayOracle(ds.truth.astype(np.float64)))
    res = run_bas_cascade(q, seed=0, path="dense")
    c = res.telemetry.cascade
    assert c is not None
    assert c.disagreement_rate == 0.0
    assert c.proxy_rows > 0 and c.correction_rows > 0
    assert res.ci.contains(truth)


def test_budget_binds_oracle_only_and_ledger_is_consistent(ds):
    """The §2 contract: at most ``budget`` unique tuples hit the expensive
    oracle across pilot + blocking + correction rounds, every one of them
    charged; the proxy runs unmetered on its own ledger."""
    budget = 500
    proxy = ArrayOracle(ds.truth.astype(np.float64))
    q = _mk_query(ds, budget=budget, proxy=proxy)
    res = run_bas_cascade(q, seed=1, path="dense")
    assert q.oracle.calls <= budget
    assert q.oracle.calls == q.oracle.charged        # no store: 1:1 pacing
    assert res.oracle_calls == q.oracle.calls
    assert res.telemetry.cascade.oracle_calls == q.oracle.calls
    # the cheap stage did the broad labelling, unconstrained by the budget
    assert proxy.budget is None
    assert proxy.calls > budget
    assert res.telemetry.cascade.proxy_calls == proxy.calls


def test_exact_shortcut_when_budget_covers_space(ds):
    q = _mk_query(ds, budget=ds.spec().n_tuples)
    res = run_bas_cascade(q, seed=0)
    assert res.telemetry.mode == "exact"
    assert res.estimate == float(ds.truth.sum())


def test_nonlinear_aggregate_falls_back_to_plain_bas(ds):
    """MIN/MAX/MEDIAN have no difference decomposition: the cascade entry
    point runs plain BAS on the chosen path instead."""
    col = ds.columns1["value"]
    g = lambda idx: col[idx[:, 0]]  # noqa: E731
    q = _mk_query(ds, agg=Agg.MEDIAN, g=g)
    res = run_bas_cascade(q, seed=0, path="dense")
    assert res.telemetry.mode == "bas"
    assert res.telemetry.cascade is None


def test_dispatch_routes_cascade_and_labels_path(ds):
    cfg = BASConfig(cascade=True)
    q = _mk_query(ds, proxy=ArrayOracle(ds.truth.astype(np.float64)))
    res = run_auto(q, cfg, seed=0)
    assert res.telemetry.mode == "bas-cascade"
    assert res.telemetry.dispatch.path == "cascade-dense"
    assert res.telemetry.cascade is not None


def test_dispatch_cascade_nonlinear_falls_through_to_plain(ds):
    col = ds.columns1["value"]
    g = lambda idx: col[idx[:, 0]]  # noqa: E731
    cfg = BASConfig(cascade=True)
    q = _mk_query(ds, agg=Agg.MIN, g=g, g_bounds=(float(col.min()), None))
    res = run_auto(q, cfg, seed=0)
    assert res.telemetry.mode == "bas"
    assert res.telemetry.dispatch.path == "dense"


def test_streaming_routed_cascade_runs(ds):
    """Forcing the streaming regime exercises the shared streaming space
    builder (histogram stratification + walk+rejection D_0) under the
    cascade pipeline."""
    q = _mk_query(ds, proxy=ArrayOracle(ds.truth.astype(np.float64)))
    res = run_bas_cascade(q, seed=2, path="streaming")
    assert res.telemetry.mode == "bas-cascade"
    assert res.telemetry.stratify is not None        # streaming stage-1 meta
    assert res.telemetry.cascade.correction_rows > 0


def test_engine_method_and_proxy_factory(ds):
    cat = Catalog()
    cat.register(Table("t1", ds.emb1, ds.columns1))
    cat.register(Table("t2", ds.emb2, ds.columns2))
    pt = ds.truth.astype(np.float64)
    eng = JoinMLEngine(cat, lambda nl, names: ds.oracle(),
                       proxy_factory=lambda nl, names: ArrayOracle(pt))
    res = eng.execute(
        "SELECT COUNT(*) FROM t1 JOIN t2 ON NL('same entity') "
        "ORACLE BUDGET 600 WITH PROBABILITY 0.95",
        method="bas-cascade", seed=4,
    )
    assert res.telemetry.mode == "bas-cascade"
    assert res.telemetry.cascade.disagreement_rate == 0.0


def test_similarity_proxy_service_group_is_content_keyed(ds):
    """The default proxy's service group is fingerprinted from the table
    embeddings: same tables -> same group (cross-query super-batch fusion +
    safe label sharing), different tables -> different group."""
    p1 = similarity_proxy(ds.spec())
    p2 = similarity_proxy(ds.spec())
    assert p1.service_group() == p2.service_group()
    assert p1.service_group()[0] == "scorer"
    other = make_clustered_tables(40, 40, n_entities=60, noise=0.4, seed=9)
    assert similarity_proxy(other.spec()).service_group() != p1.service_group()


def test_cascade_telemetry_roundtrip(ds):
    q = _mk_query(ds, proxy=ArrayOracle(ds.truth.astype(np.float64)))
    res = run_bas_cascade(q, seed=0, path="dense")
    d = res.telemetry.as_detail()
    assert d["cascade"]["proxy_group"] != d["cascade"]["oracle_group"]
    from repro.obs import QueryTelemetry

    rt = QueryTelemetry.from_detail(d)
    assert rt.cascade.proxy_calls == res.telemetry.cascade.proxy_calls
    assert rt.as_detail() == d


# ----------------------------------------------------------------------------
# OracleService integration (acceptance: bit-identical to serial)
# ----------------------------------------------------------------------------

def _served_queries(seeds):
    out = []
    for s in seeds:
        d = make_clustered_tables(64, 64, n_entities=96, noise=0.4, seed=s)
        out.append(Query(spec=d.spec(), agg=Agg.COUNT, oracle=d.oracle(),
                         budget=400,
                         proxy=ArrayOracle(d.truth.astype(np.float64))))
    return out


def test_served_cascade_bit_identical_to_serial():
    """Concurrent cascade queries through one OracleService produce exactly
    the serial estimates/CIs/ledgers; proxy traffic super-batches under its
    own ``cascade-proxy`` class and shows up in the per-class telemetry."""
    seeds = (1, 2, 3)
    serial = []
    for q, s in zip(_served_queries(seeds), seeds):
        res = run_bas_cascade(q, seed=s, path="dense")
        serial.append((res, q.oracle.calls, q.oracle.requests))

    tracker = InMemoryTracker()
    with OracleService(workers=2, max_wait_ms=20.0, tracker=tracker) as svc:
        queries = _served_queries(seeds)
        svc.attach(*[q.oracle for q in queries])

        def job(q, s):
            try:
                return run_bas_cascade(q, seed=s, path="dense")
            finally:
                svc.detach(q.oracle)

        results = serve_queries(
            svc, [lambda q=q, s=s: job(q, s) for q, s in zip(queries, seeds)]
        )
        snap = svc.snapshot()

    for (ref, calls, requests), got, q in zip(serial, results, queries):
        assert got.estimate == ref.estimate          # bit-identical
        assert got.ci.lo == ref.ci.lo and got.ci.hi == ref.ci.hi
        assert q.oracle.calls == calls               # same ledger charge
        assert q.oracle.requests == requests
        # the auto-attached proxy detached with its query
        assert q.proxy.service is None
    # proxy stage landed in its own deadline-class telemetry
    assert snap["service.class.cascade-proxy.flush_ms.count"] > 0.0
