"""Per-kernel allclose sweeps: Pallas (interpret=True on CPU) vs pure-jnp
ref.py oracles across shape/dtype grids."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.similarity import normalize

pytestmark = pytest.mark.pallas


def rand_emb(rng, n, d, dtype):
    return jnp.asarray(normalize(rng.standard_normal((n, d))), dtype)


# ----------------------------------------------------------------------------
# sim_hist
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,d", [(64, 64, 16), (128, 64, 32), (256, 128, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sim_hist_matches_ref(m, n, d, dtype):
    from repro.kernels.sim_hist.kernel import sim_hist_pallas
    from repro.kernels.sim_hist.ref import sim_hist_ref

    rng = np.random.default_rng(0)
    e1, e2 = rand_emb(rng, m, d, dtype), rand_emb(rng, n, d, dtype)
    n_bins = 256
    got = sim_hist_pallas(e1, e2, n_bins=n_bins, bm=min(64, m), bn=min(64, n),
                          bin_chunk=64, interpret=True)
    want = sim_hist_ref(e1, e2, n_bins=n_bins)
    assert int(got.sum()) == m * n
    # bf16 rounding can move boundary scores one bin; compare CDFs loosely
    np.testing.assert_allclose(
        np.cumsum(np.asarray(got)), np.cumsum(np.asarray(want)),
        atol=max(2, 0.01 * m * n),
    )
    if dtype == jnp.float32:
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("exponent", [0.5, 1.0, 2.0])
def test_sim_hist_exponent(exponent):
    from repro.kernels.sim_hist.kernel import sim_hist_pallas
    from repro.kernels.sim_hist.ref import sim_hist_ref

    rng = np.random.default_rng(1)
    e1, e2 = rand_emb(rng, 64, 16, jnp.float32), rand_emb(rng, 64, 16, jnp.float32)
    got = sim_hist_pallas(e1, e2, n_bins=128, exponent=exponent, bm=64, bn=64,
                          bin_chunk=64, interpret=True)
    want = sim_hist_ref(e1, e2, n_bins=128, exponent=exponent)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_sim_hist_ops_padding():
    from repro.kernels.sim_hist import sim_hist

    rng = np.random.default_rng(2)
    e1 = normalize(rng.standard_normal((100, 16)))   # not a block multiple
    e2 = normalize(rng.standard_normal((70, 16)))
    counts, edges = sim_hist(e1, e2, n_bins=256)
    assert counts.sum() == 100 * 70
    assert (counts >= 0).all()


# ----------------------------------------------------------------------------
# sim_topk
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,d,k", [(64, 128, 16, 4), (128, 256, 32, 8), (64, 64, 8, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sim_topk_matches_ref(m, n, d, k, dtype):
    from repro.kernels.sim_topk.kernel import sim_topk_pallas
    from repro.kernels.sim_topk.ref import sim_topk_ref

    rng = np.random.default_rng(3)
    e1, e2 = rand_emb(rng, m, d, dtype), rand_emb(rng, n, d, dtype)
    vals, idx = sim_topk_pallas(e1, e2, k=k, bm=min(64, m), bn=min(64, n),
                                interpret=True)
    rvals, ridx = sim_topk_ref(e1, e2, k=k)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rvals), atol=tol)
    # indices may differ on exact ties; values must match, and where values
    # are distinct the indices must agree
    distinct = np.abs(np.diff(np.asarray(rvals), axis=1)) > 1e-5
    same = np.asarray(idx)[:, :-1][distinct] == np.asarray(ridx)[:, :-1][distinct]
    assert same.mean() > 0.99


def test_sim_topk_ops_padding_and_validity():
    from repro.kernels.sim_topk import sim_topk

    rng = np.random.default_rng(4)
    e1 = normalize(rng.standard_normal((50, 8)))
    e2 = normalize(rng.standard_normal((37, 8)))
    vals, idx, valid = sim_topk(e1, e2, k=5)
    assert vals.shape == (50, 5) and idx.shape == (50, 5)
    assert (idx[valid] < 37).all()


# ----------------------------------------------------------------------------
# sim_sweep (fused histogram + top-k + per-block count tiles)
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,d,k", [(64, 64, 16, 4), (128, 64, 32, 8),
                                     (100, 70, 16, 8)])
def test_sim_sweep_bit_identical_to_two_kernel_path(m, n, d, k):
    """The fused sweep must reproduce the sequential sim_hist + sim_topk
    outputs bit-for-bit at fp32, and its count tiles must column-sum to the
    global histogram exactly."""
    from repro.kernels.sim_hist import sim_hist
    from repro.kernels.sim_sweep import sim_sweep
    from repro.kernels.sim_topk import sim_topk

    rng = np.random.default_rng(10)
    e1 = normalize(rng.standard_normal((m, d)))
    e2 = normalize(rng.standard_normal((n, d)))
    sw = sim_sweep(e1, e2, n_bins=256, k=k)
    counts, edges = sim_hist(e1, e2, n_bins=256)
    vals, idx, valid = sim_topk(e1, e2, k=k)
    np.testing.assert_array_equal(sw.counts, counts)
    np.testing.assert_array_equal(sw.vals, vals)
    np.testing.assert_array_equal(sw.idx, idx)
    np.testing.assert_array_equal(sw.valid, valid)
    np.testing.assert_array_equal(sw.block_counts.sum(axis=0), sw.counts)
    assert int(sw.counts.sum()) == m * n


def test_sim_sweep_scale_matches_sim_hist():
    """The per-row scale operand (k-way chain-prefix weights) must bin
    identically to sim_hist's."""
    from repro.kernels.sim_hist import sim_hist
    from repro.kernels.sim_sweep import sim_sweep

    rng = np.random.default_rng(11)
    e1 = normalize(rng.standard_normal((96, 16)))
    e2 = normalize(rng.standard_normal((80, 16)))
    scale = rng.random(96).astype(np.float32)
    sw = sim_sweep(e1, e2, n_bins=128, exponent=0.5, scale=scale)
    counts, _ = sim_hist(e1, e2, n_bins=128, exponent=0.5, scale=scale)
    np.testing.assert_array_equal(sw.counts, counts)


def test_sim_sweep_matches_ref():
    from repro.kernels.sim_sweep.kernel import sim_sweep_pallas
    from repro.kernels.sim_sweep.ref import sim_sweep_ref

    rng = np.random.default_rng(12)
    e1 = rand_emb(rng, 128, 16, jnp.float32)
    e2 = rand_emb(rng, 64, 16, jnp.float32)
    bc, vals, idx, rs = sim_sweep_pallas(e1, e2, n_bins=256, k=4, bm=64,
                                         bn=64, interpret=True)
    rbc, rvals, ridx, rrs = sim_sweep_ref(e1, e2, n_bins=256, k=4, bm=64)
    np.testing.assert_array_equal(np.asarray(bc), np.asarray(rbc))
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rvals), atol=1e-6)
    np.testing.assert_allclose(np.asarray(rs), np.asarray(rrs), rtol=1e-6)
    distinct = np.abs(np.diff(np.asarray(rvals), axis=1)) > 1e-5
    same = np.asarray(idx)[:, :-1][distinct] == np.asarray(ridx)[:, :-1][distinct]
    assert same.mean() > 0.99


@pytest.mark.parametrize("precision", ["bf16", "int8"])
def test_sim_sweep_low_precision_within_tolerance(precision):
    """bf16/int8 fast paths: exact total mass, CDF within the documented
    per-precision tolerance of the fp32 histogram."""
    from repro.configs.joinml_embedder import EMBEDDING_PRECISIONS
    from repro.kernels.sim_sweep import sim_sweep

    rng = np.random.default_rng(13)
    e1 = normalize(rng.standard_normal((100, 32)))
    e2 = normalize(rng.standard_normal((90, 32)))
    ref = sim_sweep(e1, e2, n_bins=256, k=8)
    low = sim_sweep(e1, e2, n_bins=256, k=8, precision=precision)
    assert int(low.counts.sum()) == 100 * 90
    dev = np.abs(
        np.cumsum(ref.counts) - np.cumsum(low.counts)
    ) / ref.counts.sum()
    assert dev.max() <= EMBEDDING_PRECISIONS[precision].max_cdf_shift
    # top-k of the lowp scores still finds (nearly) the same neighbours
    hit = np.mean([
        len(set(a) & set(b)) / len(a)
        for a, b in zip(low.idx.tolist(), ref.idx.tolist())
    ])
    assert hit > 0.9


def test_quantize_rows_int8_roundtrip():
    from repro.core.similarity import dequantize_rows_int8, quantize_rows_int8

    rng = np.random.default_rng(14)
    e = normalize(rng.standard_normal((50, 32)))
    e[7] = 0.0  # padding-style all-zero row
    q, rs = quantize_rows_int8(e)
    assert q.dtype == np.int8 and rs.shape == (50, 1)
    back = dequantize_rows_int8(q, rs)
    assert np.abs(back - e).max() <= (np.abs(e).max(axis=1) / 127).max() * 0.51
    assert (q[7] == 0).all() and rs[7] == 0.0


# ----------------------------------------------------------------------------
# flash_attention
# ----------------------------------------------------------------------------

@pytest.mark.parametrize(
    "b,hq,hkv,s,d", [(1, 4, 4, 128, 32), (2, 8, 2, 64, 16), (1, 4, 1, 128, 64)]
)
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(b, hq, hkv, s, d, causal, dtype):
    from repro.kernels.flash_attention.kernel import flash_attention_pallas
    from repro.kernels.flash_attention.ref import flash_attention_ref

    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.standard_normal((b, hq, s, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)), dtype)
    got = flash_attention_pallas(q, k, v, causal=causal, bq=32, bkv=32,
                                 interpret=True)
    want = flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


def test_flash_attention_window():
    from repro.kernels.flash_attention.kernel import flash_attention_pallas
    from repro.kernels.flash_attention.ref import flash_attention_ref

    rng = np.random.default_rng(6)
    q = jnp.asarray(rng.standard_normal((1, 2, 128, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 128, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 128, 16)), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=True, window=32, bq=32, bkv=32,
                                 interpret=True)
    want = flash_attention_ref(q, k, v, causal=True, window=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


# ----------------------------------------------------------------------------
# rwkv6_scan
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,t,hd", [(1, 2, 64, 16), (2, 4, 128, 32)])
@pytest.mark.parametrize("ct", [16, 64])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv6_scan_matches_ref(b, h, t, hd, ct, dtype):
    from repro.kernels.rwkv6_scan.kernel import rwkv6_scan_pallas
    from repro.kernels.rwkv6_scan.ref import rwkv6_scan_ref

    rng = np.random.default_rng(7)
    r = jnp.asarray(rng.standard_normal((b, h, t, hd)), dtype)
    k = jnp.asarray(rng.standard_normal((b, h, t, hd)) * 0.3, dtype)
    v = jnp.asarray(rng.standard_normal((b, h, t, hd)), dtype)
    w = jnp.asarray(rng.uniform(0.7, 0.999, (b, h, t, hd)), dtype)
    u = jnp.asarray(rng.standard_normal((h, hd)) * 0.1, jnp.float32)
    got = rwkv6_scan_pallas(r, k, v, w, u, ct=ct, interpret=True)
    want = rwkv6_scan_ref(r, k, v, w, u)
    tol = 1e-4 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=tol, rtol=tol)


# ----------------------------------------------------------------------------
# rglru_scan
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("b,t,r", [(1, 64, 128), (2, 256, 64), (1, 128, 512)])
@pytest.mark.parametrize("ct", [32, 128])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_scan_matches_ref(b, t, r, ct, dtype):
    from repro.kernels.rglru_scan.kernel import rglru_scan_pallas
    from repro.kernels.rglru_scan.ref import rglru_scan_ref

    rng = np.random.default_rng(8)
    a = jnp.asarray(rng.uniform(0.6, 0.999, (b, t, r)), dtype)
    g = jnp.asarray(rng.standard_normal((b, t, r)) * 0.2, dtype)
    ct_ = min(ct, t)
    br = min(512, r)
    got = rglru_scan_pallas(a, g, ct=ct_, br=br, interpret=True)
    want = rglru_scan_ref(a, g)
    tol = 1e-4 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=tol, rtol=tol)


def test_rglru_scan_long_decay_stability():
    """Long-horizon stability: with a close to 1 the doubling scan must not
    diverge from the serial reference."""
    from repro.kernels.rglru_scan.kernel import rglru_scan_pallas
    from repro.kernels.rglru_scan.ref import rglru_scan_ref

    rng = np.random.default_rng(9)
    a = jnp.asarray(rng.uniform(0.995, 0.9999, (1, 512, 128)), jnp.float32)
    g = jnp.asarray(rng.standard_normal((1, 512, 128)) * 0.05, jnp.float32)
    got = rglru_scan_pallas(a, g, ct=128, br=128, interpret=True)
    want = rglru_scan_ref(a, g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3, rtol=1e-3)
