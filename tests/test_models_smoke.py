"""Per-architecture smoke tests on reduced configs: one forward/train step on
CPU asserting output shapes + no NaNs, plus decode-vs-forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke_config
from repro.models import decode_step, forward, init_cache, init_params, loss_fn

pytestmark = pytest.mark.slow


def make_batch(cfg, b=2, s=24, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)))}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.encoder_seq, cfg.d_model)), jnp.bfloat16
        )
    if cfg.num_patches:
        batch["patches"] = jnp.asarray(
            rng.standard_normal((b, cfg.num_patches, cfg.d_model)), jnp.bfloat16
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.key(0))
    batch = make_batch(cfg)
    logits = forward(cfg, params, batch)
    b, s = batch["tokens"].shape
    expected_s = s + (cfg.num_patches if cfg.num_patches and "patches" in batch else 0)
    assert logits.shape == (b, expected_s, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, batch))(params)
    assert bool(jnp.isfinite(loss))
    finite = jax.tree.reduce(
        lambda a, g: a and bool(jnp.isfinite(g.astype(jnp.float32)).all()), grads, True
    )
    assert finite
    # one SGD step changes the loss
    params2 = jax.tree.map(lambda p, g: p - 0.05 * g.astype(p.dtype), params, grads)
    loss2 = loss_fn(cfg, params2, batch)
    assert bool(jnp.isfinite(loss2))
    assert float(loss2) != float(loss)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_shapes(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.key(0))
    b = 2
    cache = init_cache(cfg, b, 32)
    tok = jnp.zeros((b, 1), jnp.int32)
    logits, cache = decode_step(cfg, params, cache, tok, jnp.int32(0))
    assert logits.shape == (b, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    logits, cache = decode_step(cfg, params, cache, tok, jnp.int32(1))
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize(
    "arch", ["qwen2-1.5b", "llama3-8b", "olmoe-1b-7b", "rwkv6-1.6b", "recurrentgemma-9b"]
)
def test_decode_matches_forward(arch):
    """Feeding tokens one-by-one through decode_step must reproduce the
    full-sequence forward logits (the KV-cache/recurrent-state correctness
    invariant serving relies on)."""
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.key(1))
    b, s = 2, 8
    rng = np.random.default_rng(3)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)))
    full = forward(cfg, params, {"tokens": tokens}).astype(jnp.float32)

    cache = init_cache(cfg, b, s + 4)
    outs = []
    for t in range(s):
        lg, cache = decode_step(cfg, params, cache, tokens[:, t : t + 1], jnp.int32(t))
        outs.append(lg.astype(jnp.float32))
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), rtol=0.15, atol=0.15)
    # ranking agreement at the final position (what sampling consumes)
    assert (
        jnp.argmax(dec[:, -1], -1) == jnp.argmax(full[:, -1], -1)
    ).mean() >= 0.5 or np.allclose(np.asarray(dec[:, -1]), np.asarray(full[:, -1]), atol=0.2)


def test_param_counts_sane():
    from repro.configs import get_config

    approx = {
        "qwen2-1.5b": 1.5e9,
        "llama3-8b": 8e9,
        "mistral-nemo-12b": 12e9,
        "olmoe-1b-7b": 7e9,
        "qwen3-moe-235b-a22b": 235e9,
        "rwkv6-1.6b": 1.6e9,
        "recurrentgemma-9b": 9e9,
        "pixtral-12b": 12e9,
        "llama3.2-1b": 1.2e9,
        "whisper-medium": 0.76e9,
    }
    for arch, target in approx.items():
        n = get_config(arch).param_count()
        assert 0.5 * target < n < 1.8 * target, f"{arch}: {n:.2e} vs {target:.2e}"


def test_moe_capacity_drops_gracefully():
    cfg = get_smoke_config("olmoe-1b-7b", num_experts=4, num_experts_per_tok=2)
    params = init_params(cfg, jax.random.key(0))
    batch = make_batch(cfg, b=1, s=8)
    logits = forward(cfg, params, batch)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


def test_partition_specs_cover_all_params():
    from repro.models.partition import param_logical_axes

    for arch in ARCHS:
        cfg = get_smoke_config(arch)
        params = init_params(cfg, jax.random.key(0))
        axes = param_logical_axes(params)
        flat_p = jax.tree_util.tree_leaves(params)
        flat_a = jax.tree_util.tree_leaves(
            axes,
            is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(e, (str, type(None))) for e in x),
        )
        assert len(flat_p) == len(flat_a)
        for p, a in zip(flat_p, flat_a):
            assert len(a) == p.ndim, f"{arch}: spec {a} vs shape {p.shape}"
