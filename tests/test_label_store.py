"""Shared label store: charge-once oracle caching across queries.

The contract under test: attaching a :class:`repro.serve.label_store
.LabelStore` to an :class:`OracleService` changes *who pays* for a label
(first requester; everyone else rides free via ``store_hits``) but nothing
about *what* any query computes — ``calls`` advances exactly as in serial
execution, so estimates stay bit-identical, while summed ``charged`` is
bounded by the number of distinct pairs ever labelled.
"""
import threading

import numpy as np
import pytest

from repro.core import Agg, ModelOracle, Query, run_bas
from repro.core.oracle import OracleBatch
from repro.data import make_clustered_tables
from repro.serve.label_store import (
    LabelStore,
    pack_tuples,
    persistable_key,
    unpack_tuples,
)
from repro.serve.oracle_service import OracleService


def _flush_concurrently(batches):
    """Flush all batches from separate threads so they land in one service
    window; returns the futures' exceptions (None for success)."""
    outcomes = [None] * len(batches)
    barrier = threading.Barrier(len(batches))

    def go(i):
        barrier.wait()
        try:
            batches[i].flush_async().result()
        except BaseException as e:  # noqa: BLE001
            outcomes[i] = e

    threads = [threading.Thread(target=go, args=(i,))
               for i in range(len(batches))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return outcomes


def _counting_scorer(rows):
    """Deterministic pair scorer that records every row it executes."""
    lock = threading.Lock()

    def scorer(idx):
        with lock:
            rows.append(np.array(idx))
        return ((idx[:, 0] * 31 + idx[:, 1]) % 97 / 96.0).astype(np.float64)

    return scorer


# ----------------------------------------------------------------------------
# charge-once accounting
# ----------------------------------------------------------------------------

def test_concurrent_identical_pairs_charge_once():
    """Two queries racing on the same uncached pair in one window: exactly
    one backend execution, one total charge — and both oracles' ``calls``
    advance as in serial execution (the budget guarantee is untouched)."""
    rows = []
    scorer = _counting_scorer(rows)
    a = ModelOracle(scorer, threshold=0.5)
    b = ModelOracle(scorer, threshold=0.5)
    for o in (a, b):
        o.bind_sizes((64, 64))
    store = LabelStore()
    idx = np.array([[3, 4]])
    with OracleService(max_wait_ms=500.0, label_store=store) as svc:
        svc.attach(a, b)
        ba, bb = OracleBatch(a), OracleBatch(b)
        ha, hb = ba.submit(idx), bb.submit(idx)
        out = _flush_concurrently([ba, bb])
    assert out == [None, None]
    assert sum(len(r) for r in rows) == 1            # one backend execution
    np.testing.assert_array_equal(ha.labels, hb.labels)
    assert a.calls == 1 and b.calls == 1             # pacing as in serial
    assert a.charged + b.charged == 1                # ...but one charge total
    assert a.store_hits + b.store_hits == 1
    assert a.store_charge_saved + b.store_charge_saved == 1
    assert store.stats()["store_shared"] == 1
    assert store.stats()["store_misses"] == 1


def test_repeat_query_served_from_store_without_recharge():
    """A later query (fresh oracle, same scorer group) repeating already-
    stored pairs executes nothing and charges nothing."""
    rows = []
    scorer = _counting_scorer(rows)
    store = LabelStore()
    idx = np.array([[0, 1], [2, 3], [4, 5]])
    with OracleService(max_wait_ms=1.0, label_store=store) as svc:
        first = ModelOracle(scorer, threshold=0.5)
        first.bind_sizes((64, 64))
        svc.attach(first)
        first.label(idx)
        svc.detach(first)
        assert first.charged == 3 and first.store_hits == 0

        again = ModelOracle(scorer, threshold=0.5)
        again.bind_sizes((64, 64))
        svc.attach(again)
        got = again.label(idx)
        svc.detach(again)
    assert sum(len(r) for r in rows) == 3            # only the first paid
    np.testing.assert_array_equal(got, first.label(idx))
    assert again.calls == 3                          # acquired, as in serial
    assert again.charged == 0 and again.store_hits == 3
    assert store.stats()["store_hit_rate"] == 0.5
    assert store.stats()["store_entries"] == 3


def test_estimates_bit_identical_and_total_charges_bounded():
    """Full BAS queries through a stored service: estimates and CIs are
    bit-identical to serial execution, a repeat query charges zero, and the
    summed ledger charge equals the store's distinct-pair count — the
    acceptance bound."""
    ds = make_clustered_tables(60, 60, n_entities=90, noise=0.4, seed=21)
    rows = []
    scorer = _counting_scorer(rows)

    def fresh_query():
        o = ModelOracle(scorer, threshold=0.5, name="shared")
        return Query(spec=ds.spec(), agg=Agg.COUNT, oracle=o, budget=700)

    ref_q = fresh_query()
    ref = run_bas(ref_q, seed=9)
    rows.clear()

    store = LabelStore()
    results, oracles = [], []
    with OracleService(max_wait_ms=1.0, label_store=store) as svc:
        for _ in range(3):                           # 1 first + 2 repeats
            q = fresh_query()
            oracles.append(q.oracle)
            svc.attach(q.oracle)
            results.append(run_bas(q, seed=9))
            svc.detach(q.oracle)

    for res, o in zip(results, oracles):
        assert res.estimate == ref.estimate          # bit-identical
        assert res.ci.lo == ref.ci.lo and res.ci.hi == ref.ci.hi
        assert o.calls == ref_q.oracle.calls         # pacing unchanged
    assert oracles[0].charged == ref_q.oracle.calls  # first requester pays
    assert oracles[1].charged == 0                   # repeats ride free
    assert oracles[2].charged == 0
    # the acceptance bound: total charges == distinct pairs ever labelled
    total_charged = sum(o.charged for o in oracles)
    assert total_charged == store.stats()["store_entries"]
    assert sum(len(r) for r in rows) == total_charged
    # the discount is surfaced per query result
    assert results[1].detail["oracle"]["store_hits"] == oracles[1].calls
    assert results[1].detail["oracle"]["store_charge_saved"] > 0


# ----------------------------------------------------------------------------
# memory budget: LRU segment eviction + single-segment trim
# ----------------------------------------------------------------------------

def _fill(store, seg_key, keys, val=1.0):
    keys = np.asarray(sorted(keys), np.int64)
    plan = store.plan(seg_key, keys)
    store.publish(plan, np.full(len(plan.miss_keys), val))


def test_lru_segment_eviction_under_pressure():
    # 24 bytes/entry (key + val + gen): budget for ~40 entries
    store = LabelStore(max_bytes=40 * 24)
    for g in range(5):
        _fill(store, ("seg", g), range(g * 100, g * 100 + 20))
    assert store.bytes_resident <= store.max_bytes
    assert store.stats()["store_evictions"] >= 1
    # the newest (hot) segment survives; the LRU-oldest was evicted
    assert store.resident(("seg", 4), np.arange(400, 420)).all()
    assert not store.resident(("seg", 0), np.arange(0, 20)).any()


def test_lone_over_budget_segment_trims_its_oldest_half():
    store = LabelStore(max_bytes=30 * 24)
    _fill(store, ("only",), range(0, 20))            # oldest generation
    _fill(store, ("only",), range(100, 120))
    _fill(store, ("only",), range(200, 220))         # newest generation
    assert store.bytes_resident <= store.max_bytes
    assert store.stats()["store_evictions"] == 0     # nothing else to evict
    assert store.stats()["store_trimmed"] >= 20
    # oldest-inserted entries went first; the newest batch is untouched
    assert store.resident(("only",), np.arange(200, 220)).all()
    assert not store.resident(("only",), np.arange(0, 20)).any()


def test_failed_plan_cancels_reservations_retryably():
    store = LabelStore()
    keys = np.array([1, 2, 3], np.int64)
    plan = store.plan(("seg",), keys)
    waiter = store.plan(("seg",), keys)              # rides plan's call
    assert len(waiter.miss_keys) == 0 and len(waiter.wait) == 1
    store.cancel(plan, RuntimeError("backend down"))
    with pytest.raises(RuntimeError):
        waiter.wait[0][0].result(timeout=1.0)        # waiter fails retryably
    retry = store.plan(("seg",), keys)               # keys reservable again
    assert len(retry.miss_keys) == 3
    store.publish(retry, np.ones(3))
    assert store.resident(("seg",), keys).all()


# ----------------------------------------------------------------------------
# persistence
# ----------------------------------------------------------------------------

def test_persistence_roundtrip_and_process_local_exclusion(tmp_path):
    root = str(tmp_path / "labels")
    store = LabelStore(root=root)
    stable = (("scorer", "shared", 0.5), ("sizes", 64, 64))
    assert persistable_key(stable)
    _fill(store, stable, [10, 20, 30], val=0.25)
    # an id()-derived (process-local) group coalesces in memory but must
    # never be persisted — its key is meaningless in another process
    local = ModelOracle(lambda i: np.zeros(len(i)), threshold=0.5)
    local_key = (local.service_group(), ("sizes", 64, 64))
    assert not persistable_key(local_key)
    _fill(store, local_key, [1, 2, 3])
    assert store.save() == 1                         # only the stable segment

    revived = LabelStore(root=root)
    assert revived.loads == 1
    assert revived.resident(stable, np.array([10, 20, 30])).all()
    assert not revived.resident(local_key, np.array([1, 2, 3])).any()
    plan = revived.plan(stable, np.array([10, 20, 30], np.int64))
    assert len(plan.miss_keys) == 0
    np.testing.assert_array_equal(plan.hit_vals, [0.25, 0.25, 0.25])


def test_service_restart_keeps_hot_labels(tmp_path):
    """End to end: a named oracle's labels survive OracleService.close() ->
    new store -> new service; the repeat query executes zero backend rows."""
    root = str(tmp_path / "labels")
    rows = []
    scorer = _counting_scorer(rows)
    idx = np.array([[1, 2], [3, 4], [5, 6]])

    with OracleService(max_wait_ms=1.0,
                       label_store=LabelStore(root=root)) as svc:
        o = ModelOracle(scorer, threshold=0.5, name="persisted")
        o.bind_sizes((64, 64))
        svc.attach(o)
        first = o.label(idx)
        svc.detach(o)
    # close() saved; a fresh service + store + oracle serves from disk
    with OracleService(max_wait_ms=1.0,
                       label_store=LabelStore(root=root)) as svc:
        o2 = ModelOracle(scorer, threshold=0.5, name="persisted")
        o2.bind_sizes((64, 64))
        svc.attach(o2)
        again = o2.label(idx)
        svc.detach(o2)
    np.testing.assert_array_equal(again, first)
    assert sum(len(r) for r in rows) == 3            # restart cost no charges
    assert o2.charged == 0 and o2.store_hits == 3


# ----------------------------------------------------------------------------
# the transport (raw-segment) path
# ----------------------------------------------------------------------------

def test_wire_exec_answers_are_store_served():
    """Raw EXEC segments go through the same store consultation: duplicate
    rows inside one request cost one execution, and a repeat request from
    another connection executes nothing."""
    from repro.serve.transport import OracleServiceServer, ServiceConnection

    rows = []
    lock = threading.Lock()

    def fn(idx):
        with lock:
            rows.append(np.array(idx))
        return (idx.sum(axis=1) % 2).astype(np.float64)

    idx = np.array([[5, 6], [1, 2], [5, 6], [3, 4]])  # unsorted + duplicate
    with OracleServiceServer({"parity": fn}, max_wait_ms=2.0,
                             label_store=LabelStore()) as server:
        with ServiceConnection(server.address) as conn:
            got = conn.execute("parity", idx)
            np.testing.assert_array_equal(got, idx.sum(1) % 2)
            assert sum(len(r) for r in rows) == 3     # unique rows only
        with ServiceConnection(server.address) as conn2:
            again = conn2.execute("parity", idx[::-1])
            np.testing.assert_array_equal(again, idx[::-1].sum(1) % 2)
        stats = server.service.stats()
    assert sum(len(r) for r in rows) == 3             # repeat executed nothing
    assert stats["store_hits"] >= 3


def test_pack_roundtrip_and_overflow_guard():
    idx = np.array([[0, 1], [2**31 - 1, 7], [123456, 654321]], np.int64)
    keys = pack_tuples(idx)
    np.testing.assert_array_equal(unpack_tuples(keys, 2), idx)
    assert pack_tuples(np.array([[2**31, 0]])) is None   # exceeds 63//2 bits
    assert pack_tuples(np.array([[-1, 0]])) is None
