"""Regenerate the §Dry-run and §Roofline tables of EXPERIMENTS.md from the
dry-run JSON records.

    PYTHONPATH=src python experiments/make_report.py [--dir experiments/dryrun]
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.roofline import hw  # noqa: E402
from repro.roofline.report import load_records, roofline_fraction  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    recs = [r for r in load_records(args.dir)
            if r.get("rules", "default") == "default" and not r.get("tag")]

    print("### Dry-run summary (both meshes)\n")
    for mesh in ("16x16", "2x16x16"):
        rs = [r for r in recs if r["mesh"] == mesh]
        ok = sum(r["status"] == "ok" for r in rs)
        sk = sum(r["status"] == "skipped" for r in rs)
        er = len(rs) - ok - sk
        print(f"* **{mesh}**: {ok} compiled, {sk} skipped (documented), {er} errors "
              f"of {len(rs)} cells")
    print()

    print("### Roofline table (single pod, 256 chips; seconds per step)\n")
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant | "
           "kernel-adj M | kernel-adj bound | MODEL_FLOPS/chip | useful | "
           "mem/chip GiB | roofline frac |")
    print(hdr)
    print("|" + "---|" * 12)
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != "16x16":
            continue
        if r["status"] == "skipped":
            print(f"| {r['arch']} | {r['shape']} | — | — | — | *skipped: "
                  f"{r['reason']}* | — | — | — | — | — | — |")
            continue
        if r["status"] != "ok":
            print(f"| {r['arch']} | {r['shape']} | ERROR |||||||||| ")
            continue
        rf = r["roofline"]
        ka = r.get("roofline_kernel_adj", rf)
        adj_bound = max(ka["compute_s"], ka["memory_s"], ka["collective_s"])
        frac = r["model_flops_per_chip"] / (adj_bound * hw.PEAK_FLOPS_BF16)
        print(
            "| {arch} | {shape} | {c:.3e} | {m:.3e} | {x:.3e} | {dom} | "
            "{kam:.3e} | {kab:.3e} | {mf:.2e} | {ur:.2f} | {mem:.1f} | "
            "{frac:.4f} |".format(
                arch=r["arch"], shape=r["shape"], c=rf["compute_s"],
                m=rf["memory_s"], x=rf["collective_s"], dom=rf["dominant"],
                kam=ka["memory_s"], kab=adj_bound,
                mf=r["model_flops_per_chip"], ur=r["useful_compute_ratio"],
                mem=r["memory"]["total_bytes"] / 2**30, frac=frac,
            )
        )
    print()
    print("### Multi-pod (2x16x16, 512 chips) — pod axis shards\n")
    print("| arch | shape | compile s | memory/chip GiB | collective s |")
    print("|---|---|---|---|---|")
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != "2x16x16" or r["status"] != "ok":
            continue
        print(f"| {r['arch']} | {r['shape']} | {r['compile_s']:.1f} | "
              f"{r['memory']['total_bytes']/2**30:.2f} | "
              f"{r['roofline']['collective_s']:.3e} |")


if __name__ == "__main__":
    main()
